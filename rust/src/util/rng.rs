//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set has no `rand` crate, so we implement the two
//! standard small generators ourselves: splitmix64 for seeding and
//! xoshiro256** for the main stream. Everything in the repository that
//! needs randomness (weight init, pruning masks, synthetic workloads,
//! property tests) goes through [`Rng`] so runs are reproducible from a
//! single `u64` seed.

/// splitmix64 step: used to expand a single u64 seed into a full
/// xoshiro256** state (the construction recommended by the xoshiro authors).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of randomness.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough for
    /// our workloads; n must be > 0).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-12 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation, as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli trial with probability `p` of `true`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fill a buffer with He-style (kaiming) normal weights for a layer
    /// with the given fan-in: N(0, sqrt(2 / fan_in)).
    pub fn fill_he(&mut self, buf: &mut [f32], fan_in: usize) {
        let std = (2.0 / fan_in.max(1) as f64).sqrt() as f32;
        for x in buf.iter_mut() {
            *x = self.normal_f32(0.0, std);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(13);
        assert!((0..100).all(|_| !r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0 + 1e-9)));
    }

    #[test]
    fn he_init_scale() {
        let mut r = Rng::new(17);
        let mut buf = vec![0f32; 50_000];
        r.fill_he(&mut buf, 128);
        let var = buf.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
            / buf.len() as f64;
        let expect = 2.0 / 128.0;
        assert!((var - expect).abs() / expect < 0.1, "var={var} expect={expect}");
    }
}
