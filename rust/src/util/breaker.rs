//! Per-site circuit breakers for the self-healing serving ladder.
//!
//! A [`Breaker`] guards one fault *site* — the same granularity
//! `util::fault` keys its injection points by: a (pipeline, stage
//! index) pair in practice. HPIPE's premise is statically specialized
//! per-layer hardware, so a fault is inherently localized to one stage;
//! the breaker mirrors that granularity in software. One stage tripping
//! must not condemn every plan the model owns.
//!
//! States (the classic three):
//!
//! ```text
//! Closed ──(threshold consecutive failures / forced trip)──▶ Open
//! Open ──(cool-down elapsed, try_probe wins)──▶ HalfOpen
//! HalfOpen ──(probe success)──▶ Closed        [a recovery]
//! HalfOpen ──(probe failure)──▶ Open          [cool-down doubles]
//! ```
//!
//! Everything is atomics so the coordinator's feeder thread and the
//! executor can read degrade/recovery state through a shared reference
//! — no `&mut`, no locks on the hot path. Time is passed in as
//! epoch-nanoseconds (`util::timer::epoch_ns`) rather than read
//! internally, keeping trip/probe arithmetic deterministic in tests.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};

/// Breaker state, stored as a `u8` atomic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: requests take the guarded (pipelined) path.
    Closed,
    /// Tripped: the guarded path is bypassed until cool-down elapses.
    Open,
    /// One probe is in flight through the guarded path.
    HalfOpen,
}

const CLOSED: u8 = 0;
const OPEN: u8 = 1;
const HALF_OPEN: u8 = 2;

fn decode(raw: u8) -> BreakerState {
    match raw {
        OPEN => BreakerState::Open,
        HALF_OPEN => BreakerState::HalfOpen,
        _ => BreakerState::Closed,
    }
}

/// Tunables shared by every breaker of a model (immutable after build).
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive failures at one site that trip it (the runtime's
    /// retry-once ladder means 2 = "a fault and its failed retry").
    pub threshold: u32,
    /// Initial cool-down before a tripped site may probe, in ns
    /// (`--recover-after-ms`).
    pub cooldown_ns: u64,
    /// Cap for the exponential back-off (each failed probe doubles the
    /// cool-down up to this).
    pub max_cooldown_ns: u64,
    /// `false` (`--no-recover`) makes a trip permanent: [`Breaker::try_probe`]
    /// never grants a probe and the site stays Open until reload —
    /// PR 6's sticky degrade, as the escape hatch.
    pub recover: bool,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            threshold: 2,
            cooldown_ns: 50_000_000,           // 50 ms
            max_cooldown_ns: 10_000_000_000,   // 10 s
            recover: true,
        }
    }
}

impl BreakerConfig {
    /// Config with the cool-down set from milliseconds (the CLI knob).
    pub fn with_cooldown_ms(ms: u64) -> Self {
        BreakerConfig { cooldown_ns: ms.saturating_mul(1_000_000), ..Default::default() }
    }
}

/// One site's breaker. All-atomic; share it behind `&`/`Arc` freely.
#[derive(Debug)]
pub struct Breaker {
    state: AtomicU8,
    consecutive: AtomicU32,
    trips: AtomicU64,
    recoveries: AtomicU64,
    /// epoch-ns when the breaker last entered Open.
    opened_at_ns: AtomicU64,
    /// Current (backed-off) cool-down; resets to the base on recovery.
    cooldown_ns: AtomicU64,
    cfg: BreakerConfig,
}

impl Breaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        Breaker {
            state: AtomicU8::new(CLOSED),
            consecutive: AtomicU32::new(0),
            trips: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            opened_at_ns: AtomicU64::new(0),
            cooldown_ns: AtomicU64::new(cfg.cooldown_ns),
            cfg,
        }
    }

    pub fn state(&self) -> BreakerState {
        decode(self.state.load(Ordering::Acquire))
    }

    pub fn is_closed(&self) -> bool {
        self.state() == BreakerState::Closed
    }

    /// Times this site has tripped (Closed/HalfOpen -> Open).
    pub fn trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Times a probe through this site succeeded (HalfOpen -> Closed).
    pub fn recoveries(&self) -> u64 {
        self.recoveries.load(Ordering::Relaxed)
    }

    /// The currently scheduled cool-down (base × 2^failed-probes, capped).
    pub fn current_cooldown_ns(&self) -> u64 {
        self.cooldown_ns.load(Ordering::Relaxed)
    }

    /// Record a failure at this site. In Closed, counts toward the
    /// consecutive-failure threshold and trips when reached; in
    /// HalfOpen, the probe failed — re-open with the cool-down doubled.
    /// Returns `true` if this call tripped the breaker (entered Open).
    pub fn record_failure(&self, now_ns: u64) -> bool {
        match self.state() {
            BreakerState::Closed => {
                let seen = self.consecutive.fetch_add(1, Ordering::AcqRel) + 1;
                if seen >= self.cfg.threshold {
                    self.open(now_ns);
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => {
                let next = self
                    .current_cooldown_ns()
                    .saturating_mul(2)
                    .min(self.cfg.max_cooldown_ns);
                self.cooldown_ns.store(next, Ordering::Relaxed);
                self.open(now_ns);
                true
            }
            BreakerState::Open => false,
        }
    }

    /// Trip unconditionally (the runtime's ladder calls this when a
    /// retry faults at a *different* site than the first attempt: the
    /// retry site has only one consecutive failure, but the model-level
    /// contract — two faults in one batch demote the pipe — still
    /// holds). Returns `true` if the breaker was not already Open.
    pub fn force_trip(&self, now_ns: u64) -> bool {
        if self.state() == BreakerState::Open {
            return false;
        }
        self.open(now_ns);
        true
    }

    fn open(&self, now_ns: u64) {
        self.opened_at_ns.store(now_ns, Ordering::Relaxed);
        self.consecutive.store(0, Ordering::Relaxed);
        self.trips.fetch_add(1, Ordering::Relaxed);
        self.state.store(OPEN, Ordering::Release);
    }

    /// Record a success through the guarded path. In HalfOpen this is a
    /// recovery: close, reset the consecutive count and the back-off.
    /// Returns `true` when the call recovered the site.
    pub fn record_success(&self) -> bool {
        match self.state() {
            BreakerState::HalfOpen => {
                self.consecutive.store(0, Ordering::Relaxed);
                self.cooldown_ns.store(self.cfg.cooldown_ns, Ordering::Relaxed);
                self.recoveries.fetch_add(1, Ordering::Relaxed);
                self.state.store(CLOSED, Ordering::Release);
                true
            }
            BreakerState::Closed => {
                self.consecutive.store(0, Ordering::Relaxed);
                false
            }
            BreakerState::Open => false,
        }
    }

    /// Ask for a probe: if the breaker is Open, recovery is enabled and
    /// the cool-down has elapsed, CAS to HalfOpen. Exactly one caller
    /// wins; everyone else keeps the bypass path. The winner MUST
    /// follow up with [`record_success`] or [`record_failure`].
    pub fn try_probe(&self, now_ns: u64) -> bool {
        if !self.cfg.recover || self.state() != BreakerState::Open {
            return false;
        }
        let ready = now_ns.saturating_sub(self.opened_at_ns.load(Ordering::Relaxed))
            >= self.current_cooldown_ns();
        ready
            && self
                .state
                .compare_exchange(OPEN, HALF_OPEN, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(cooldown_ns: u64) -> BreakerConfig {
        BreakerConfig { cooldown_ns, max_cooldown_ns: cooldown_ns * 8, ..Default::default() }
    }

    #[test]
    fn threshold_consecutive_failures_trip() {
        let b = Breaker::new(cfg(100));
        assert!(!b.record_failure(0), "first failure must not trip");
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.record_failure(10), "second consecutive failure trips");
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn success_resets_the_consecutive_count() {
        let b = Breaker::new(cfg(100));
        b.record_failure(0);
        assert!(!b.record_success(), "closed success is not a recovery");
        assert!(!b.record_failure(10), "count restarted: one failure again");
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn probe_gates_on_cooldown_and_single_winner() {
        let b = Breaker::new(cfg(100));
        b.force_trip(1_000);
        assert!(!b.try_probe(1_050), "cool-down not elapsed");
        assert!(b.try_probe(1_100), "cool-down elapsed: probe granted");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.try_probe(1_200), "only one probe may be in flight");
    }

    #[test]
    fn probe_success_recovers_and_resets_backoff() {
        let b = Breaker::new(cfg(100));
        b.force_trip(0);
        assert!(b.try_probe(100));
        assert!(b.record_success(), "half-open success is a recovery");
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.recoveries(), 1);
        assert_eq!(b.current_cooldown_ns(), 100, "back-off resets on recovery");
    }

    #[test]
    fn failed_probes_back_off_exponentially_to_the_cap() {
        let b = Breaker::new(cfg(100));
        b.force_trip(0);
        let mut now = 0u64;
        let mut want = 100u64;
        for _ in 0..5 {
            now += b.current_cooldown_ns();
            assert!(b.try_probe(now));
            assert!(b.record_failure(now), "failed probe re-opens");
            want = (want * 2).min(800);
            assert_eq!(b.current_cooldown_ns(), want);
        }
        assert_eq!(b.current_cooldown_ns(), 800, "back-off capped at max");
    }

    #[test]
    fn no_recover_makes_a_trip_permanent() {
        let b = Breaker::new(BreakerConfig {
            recover: false,
            cooldown_ns: 1,
            ..Default::default()
        });
        b.record_failure(0);
        b.record_failure(0);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.try_probe(u64::MAX), "--no-recover: probes never granted");
    }

    #[test]
    fn force_trip_is_idempotent_while_open() {
        let b = Breaker::new(cfg(100));
        assert!(b.force_trip(0));
        assert!(!b.force_trip(10), "already open: no second trip counted");
        assert_eq!(b.trips(), 1);
    }
}
