//! Tiny command-line parser (no `clap` in the offline vendor set).
//!
//! Grammar: `hpipe <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key=value` or `--key value`; `--flag` with no
//! value is boolean true.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(
            self.flags.get(key).map(|s| s.as_str()),
            Some("true") | Some("1") | Some("yes")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("compile resnet50 extra");
        assert_eq!(a.subcommand.as_deref(), Some("compile"));
        assert_eq!(a.positional, vec!["resnet50", "extra"]);
    }

    #[test]
    fn flags_space_and_equals() {
        let a = parse("simulate --dsp-target 5000 --device=s10_2800 --verbose");
        assert_eq!(a.usize("dsp-target", 0), 5000);
        assert_eq!(a.str("device", ""), "s10_2800");
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.usize("batch", 4), 4);
        assert_eq!(a.f64("sparsity", 0.85), 0.85);
        assert_eq!(a.opt("missing"), None);
    }

    #[test]
    fn flag_before_subcommand_value_not_swallowed() {
        // `--flag sub`: "sub" is consumed as the flag's value by design;
        // callers put flags after the subcommand.
        let a = parse("compile --net resnet50 --sparsity 0.85");
        assert_eq!(a.subcommand.as_deref(), Some("compile"));
        assert_eq!(a.str("net", ""), "resnet50");
        assert_eq!(a.f64("sparsity", 0.0), 0.85);
    }
}
