//! Minimal `anyhow`-style error handling.
//!
//! The offline vendor set has no `anyhow`/`thiserror`, so the application
//! layers (graphdef I/O, codegen, runtime, coordinator, CLI, examples)
//! use this module instead: a single string-carrying [`Error`], a
//! [`Result`] alias, a [`Context`] extension trait for `Result`/`Option`,
//! and the [`err!`]/[`bail!`]/[`ensure!`] macros. Typed errors that code
//! matches on (e.g. `GraphError`, `SimError`) stay as enums and convert
//! into [`Error`] via the blanket `From<E: std::error::Error>` impl —
//! which is also why `Error` itself deliberately does *not* implement
//! `std::error::Error` (the same coherence trick `anyhow` uses).

use std::fmt;

/// A dynamic error: a message plus the chain of contexts added via
/// [`Context::context`], rendered outermost-first like `anyhow`.
pub struct Error {
    msg: String,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context line.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error {
            msg: format!("{c}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: no `impl std::error::Error for Error` — that would overlap with
// the blanket conversion below (see module docs).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Context` analog for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (the `anyhow!` analog).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => { $crate::util::error::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::err!($($arg)*).into()) };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn std_errors_convert() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("writing header").unwrap_err();
        assert!(e.to_string().starts_with("writing header: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing key '{}'", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing key 'x'");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(n: usize) -> Result<usize> {
            crate::ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                crate::bail!("five is right out");
            }
            Ok(n)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "n too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        let e = crate::err!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn error_context_wraps() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
    }
}
