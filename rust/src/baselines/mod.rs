//! The accelerators HPIPE is compared against (§VI).
//!
//! The paper itself compares against *reported numbers* — NVIDIA's
//! published V100 ResNet-50 batch sweep [25], Brainwave's ISCA paper
//! [17], the DLA performance model [12], Lu et al. [1] and Wu et al.
//! [27] — plus the A10→S10 scaling rules of §VI-A. This module encodes
//! those published data points and scaling rules, and adds *quantitative*
//! models of the three activation-partitioning architectures of §III
//! (Distribute / Local Transfer / Pipeline) so Table I's qualitative
//! comparison can be regenerated as measured numbers (Table I bench).

pub mod partitioning;

use crate::graph::{Graph, Op};

/// One (latency_ms, throughput_img_s, batch) point of a published curve.
#[derive(Clone, Copy, Debug)]
pub struct PerfPoint {
    pub batch: usize,
    pub latency_ms: f64,
    pub throughput: f64,
}

/// NVIDIA V100 ResNet-50 inference, mixed precision, from the Tesla
/// deep-learning product performance page the paper cites [25]
/// (archived 2019-08-17). Throughput at B=1 anchors the paper's
/// "nearly 4x" claim (HPIPE 4550 vs V100 ~1155 img/s).
pub fn v100_resnet50_curve() -> Vec<PerfPoint> {
    vec![
        PerfPoint { batch: 1, latency_ms: 0.87, throughput: 1155.0 },
        PerfPoint { batch: 2, latency_ms: 1.04, throughput: 1928.0 },
        PerfPoint { batch: 4, latency_ms: 1.48, throughput: 2708.0 },
        PerfPoint { batch: 8, latency_ms: 2.44, throughput: 3279.0 },
        PerfPoint { batch: 16, latency_ms: 4.22, throughput: 3793.0 },
        PerfPoint { batch: 32, latency_ms: 7.52, throughput: 4255.0 },
        PerfPoint { batch: 64, latency_ms: 14.2, throughput: 4505.0 },
        PerfPoint { batch: 128, latency_ms: 27.4, throughput: 4670.0 },
    ]
}

/// V100 MobileNet-V1 point used in Table IV.
pub const V100_MOBILENET_V1: PerfPoint = PerfPoint {
    batch: 1,
    latency_ms: 0.22,
    throughput: 4605.0,
};

/// Brainwave ResNet-50 on Arria 10 (ISCA'18 [17]): the paper scales the
/// A10 number by the published peak-TFLOPs ratio to estimate S10.
pub const BRAINWAVE_A10: PerfPoint = PerfPoint {
    batch: 1,
    latency_ms: 1.8,
    throughput: 559.0,
};
/// Peak TFLOPs ratio S10 : A10 from [17] (90 vs ~18 TFLOPs ≈ 5.0×; the
/// paper's Fig 8 uses the published "Peak TFLOPs" pair).
pub const BRAINWAVE_S10_SCALE: f64 = 5.0;

/// DLA-like performance-model number on Arria 10 (the paper's [12]
/// comparison), ResNet-50 batch 1.
pub const DLA_A10: PerfPoint = PerfPoint {
    batch: 1,
    latency_ms: 5.5,
    throughput: 181.0,
};
/// §VI-A: "we scaled them by a compounded 3.4x for the ~2.3x increase in
/// 18x18 multipliers and a 1.5x improvement in frequency."
pub const DLA_S10_SCALE: f64 = 3.4;

/// Scale a published A10 point to an S10 estimate (throughput × k,
/// latency ÷ k) — perfect-scaling assumption, as in the paper.
pub fn scale_point(p: PerfPoint, k: f64) -> PerfPoint {
    PerfPoint {
        batch: p.batch,
        latency_ms: p.latency_ms / k,
        throughput: p.throughput * k,
    }
}

/// Lu et al. [1] sparse-CNN FPGA accelerator (Table V row).
pub struct LuEtAl;
impl LuEtAl {
    pub const DEVICE: &'static str = "Xilinx Zynq ZCU102";
    pub const FREQ_MHZ: f64 = 200.0;
    pub const LOGIC_UTIL: f64 = 0.92;
    pub const DSP_UTIL: f64 = 0.45;
    pub const BRAM_UTIL: f64 = 0.48;
}

/// Wu et al. [27] MobileNet-V2 FPGA accelerator (Table IV column).
pub struct WuEtAl;
impl WuEtAl {
    pub const DEVICE: &'static str = "Zynq ZU9";
    pub const DSPS_USED: usize = 2_070; // 27x18 multipliers
    pub const PRECISION_BITS: usize = 8;
    pub const THROUGHPUT_B1: f64 = 810.0;
    pub const FREQ_MHZ: f64 = 333.0;
    pub const TOP1_ACC: f64 = 0.681;
}

/// Published accuracy rows of Table III.
pub struct Table3Row {
    pub name: &'static str,
    pub sparsity: f64,
    pub winograd: bool,
    pub precision_bits: u32,
    pub format: &'static str,
    pub top1: Option<f64>,
}

pub fn table3_published() -> Vec<Table3Row> {
    vec![
        Table3Row { name: "V100", sparsity: 0.0, winograd: false, precision_bits: 8, format: "Fixed", top1: Some(0.7493) },
        Table3Row { name: "Brainwave", sparsity: 0.0, winograd: false, precision_bits: 11, format: "Block Float", top1: Some(0.76) },
        Table3Row { name: "HPIPE", sparsity: 0.85, winograd: false, precision_bits: 16, format: "Fixed", top1: Some(0.719) },
        Table3Row { name: "DLA-Like", sparsity: 0.0, winograd: true, precision_bits: 16, format: "Fixed", top1: None },
    ]
}

/// HPIPE's published headline numbers (for EXPERIMENTS.md comparisons).
pub struct PaperHpipe;
impl PaperHpipe {
    pub const RESNET50_THROUGHPUT: f64 = 4550.0;
    pub const RESNET50_FREQ_MHZ: f64 = 580.0;
    pub const RESNET50_DSPS: usize = 5_022;
    pub const RESNET50_M20KS: usize = 11_278;
    pub const RESNET50_ALMS: usize = 591_882;
    pub const MOBILENET_V1_THROUGHPUT: f64 = 5_157.0;
    pub const MOBILENET_V1_FREQ_MHZ: f64 = 430.0;
    pub const MOBILENET_V1_DSPS: usize = 5_133;
    pub const MOBILENET_V2_THROUGHPUT: f64 = 4_539.0;
    pub const MOBILENET_V2_FREQ_MHZ: f64 = 390.0;
    pub const MOBILENET_V2_DSPS: usize = 2_964;
    pub const MOBILENET_V2_LATENCY_MS: f64 = 1.1;
    pub const MOBILENET_V1_LATENCY_MS: f64 = 0.65;
}

/// Count 18×18-equivalent multipliers a graph needs per image at a given
/// sparsity — the normalization Table IV uses ("divide our throughput by
/// the number of 18x18 multipliers we use").
pub fn throughput_per_multiplier(throughput: f64, multipliers: usize) -> f64 {
    throughput / multipliers.max(1) as f64
}

/// Effective MAC/s an accelerator must sustain for a graph at a
/// throughput (sanity metric for the roofline discussion).
pub fn required_mac_rate(graph: &Graph, sparsity: f64, throughput: f64) -> f64 {
    let dense = graph.macs().unwrap_or(0) as f64;
    // Depthwise + FC are small; apply sparsity to conv MACs only would
    // need a per-layer walk; the paper prunes everything but depthwise.
    let sparse_frac: f64 = {
        let mut prunable = 0u64;
        let mut total = 0u64;
        let shapes = graph.infer_shapes().unwrap();
        for n in &graph.nodes {
            match n.op {
                Op::Conv2D { .. } | Op::MatMul => {
                    let out = &shapes[&n.name];
                    let w = &shapes[&n.inputs[1]];
                    let macs = if w.len() == 4 {
                        (out[1] * out[2] * w[0] * w[1] * w[2] * w[3]) as u64
                    } else {
                        (w[0] * w[1]) as u64
                    };
                    prunable += macs;
                    total += macs;
                }
                Op::DepthwiseConv2d { .. } => {
                    let out = &shapes[&n.name];
                    let w = &shapes[&n.inputs[1]];
                    total += (out[1] * out[2] * out[3] * w[0] * w[1]) as u64;
                }
                _ => {}
            }
        }
        if total == 0 {
            0.0
        } else {
            prunable as f64 / total as f64
        }
    };
    dense * (1.0 - sparsity * sparse_frac) * throughput
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{resnet50, NetConfig};

    #[test]
    fn v100_curve_monotone() {
        let c = v100_resnet50_curve();
        assert!(c.windows(2).all(|w| w[0].batch < w[1].batch));
        assert!(c.windows(2).all(|w| w[0].throughput < w[1].throughput));
        assert!(c.windows(2).all(|w| w[0].latency_ms < w[1].latency_ms));
    }

    #[test]
    fn paper_headline_ratios() {
        // The paper's "nearly 4x the V100 at batch 1".
        let v100_b1 = v100_resnet50_curve()[0].throughput;
        let ratio = PaperHpipe::RESNET50_THROUGHPUT / v100_b1;
        assert!((3.5..4.5).contains(&ratio), "ratio={ratio}");
        // "outperforms Brainwave ... by 1.6x" (vs scaled S10 estimate)
        let bw = scale_point(BRAINWAVE_A10, BRAINWAVE_S10_SCALE);
        let r2 = PaperHpipe::RESNET50_THROUGHPUT / bw.throughput;
        assert!((1.3..2.0).contains(&r2), "brainwave ratio={r2}");
        // "and DLA-Like by 7.4x"
        let dla = scale_point(DLA_A10, DLA_S10_SCALE);
        let r3 = PaperHpipe::RESNET50_THROUGHPUT / dla.throughput;
        assert!((6.0..9.0).contains(&r3), "dla ratio={r3}");
    }

    #[test]
    fn table4_per_multiplier_normalization() {
        // Paper: "throughput per multiplier 1.95x higher for HPIPE".
        let wu = throughput_per_multiplier(WuEtAl::THROUGHPUT_B1, WuEtAl::DSPS_USED);
        let hpipe = throughput_per_multiplier(
            PaperHpipe::MOBILENET_V2_THROUGHPUT,
            PaperHpipe::MOBILENET_V2_DSPS * 2, // 2 mults per S10 DSP
        );
        let ratio = hpipe / wu;
        assert!((1.7..2.2).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn scaling_preserves_product() {
        let p = scale_point(BRAINWAVE_A10, 5.0);
        assert!((p.throughput / BRAINWAVE_A10.throughput - 5.0).abs() < 1e-9);
        assert!((BRAINWAVE_A10.latency_ms / p.latency_ms - 5.0).abs() < 1e-9);
    }

    #[test]
    fn required_mac_rate_sanity() {
        let g = resnet50(NetConfig::imagenet());
        // dense at 1 img/s ≈ 3.9 GMAC/s
        let dense = required_mac_rate(&g, 0.0, 1.0);
        assert!((3.5e9..4.3e9).contains(&dense));
        // 85% sparsity cuts conv MACs; FC is tiny, so ~0.15x
        let sparse = required_mac_rate(&g, 0.85, 1.0);
        let frac = sparse / dense;
        assert!((0.14..0.2).contains(&frac), "frac={frac}");
    }
}
