//! Quantitative models of the three activation-partitioning architectures
//! of §III-B (Fig 2, Table I): Distribute (Intel-DLA-like), Local
//! Transfer (SCNN-like), and HPIPE's Pipeline.
//!
//! The paper compares these qualitatively (Table I grades each
//! architecture Poor/Good/Excellent on five axes). We make each axis a
//! measured quantity over a ResNet-50 layer suite, following the paper's
//! own §III-B/III-C reasoning for what each axis *means*:
//!
//! * **Activation locality** — energy-weighted activation traffic:
//!   global-buffer round trips (Distribute, with per-PE-group broadcast
//!   duplication), inter-PE halo exchange (Local Transfer), or direct
//!   producer→consumer wires (Pipeline). Energy weights: 8 units/byte
//!   through a global buffer or the PE mesh, 1 unit/byte over dedicated
//!   wires.
//! * **Address computation** — independent address-generation units.
//! * **Shape flexibility** — the *worst-case* PE utilization over the
//!   suite (§III-B2: LT "cannot be split across many PEs when the height
//!   and width dimensions shrink").
//! * **Weight bandwidth** — weight fetch bytes per image (§III-C:
//!   Pipeline re-reads all weights once per output line).
//! * **Latency** — PE-cycles to finish one image: Distribute and Local
//!   Transfer "use all of their multipliers to compute every intermediate
//!   activation"; Pipeline divides its multipliers across all layers and
//!   pays pipeline fill.

use crate::arch::StageGeometry;

/// A layer's workload for the partitioning comparison.
#[derive(Clone, Debug)]
pub struct LayerWork {
    pub geo: StageGeometry,
    /// Nonzero fraction of the weights (1.0 = dense).
    pub density: f64,
}

impl LayerWork {
    pub fn dense_macs(&self) -> f64 {
        (self.geo.out_h * self.geo.out_w * self.geo.out_c) as f64
            * (self.geo.kh * self.geo.kw * self.geo.in_c) as f64
    }

    pub fn sparse_macs(&self) -> f64 {
        self.dense_macs() * self.density
    }

    pub fn nonzero_weights(&self) -> f64 {
        (self.geo.kh * self.geo.kw * self.geo.in_c * self.geo.out_c) as f64 * self.density
    }

    /// Activation bytes touched per image (read + write, 16-bit).
    pub fn activation_bytes(&self) -> f64 {
        let in_elems = (self.geo.out_h * self.geo.stride * self.geo.in_w * self.geo.in_c) as f64;
        let out_elems = (self.geo.out_h * self.geo.out_w * self.geo.out_c) as f64;
        (in_elems + out_elems) * 2.0
    }
}

/// Measured axes of Table I for one architecture over one layer.
#[derive(Clone, Copy, Debug, Default)]
pub struct Axes {
    /// Energy-weighted activation traffic per image — lower is better.
    pub activation_traffic: f64,
    /// Independent address-computation units — lower is better.
    pub address_units: f64,
    /// PE utilization for THIS layer (suite aggregation takes the min).
    pub pe_utilization: f64,
    /// Weight-fetch bytes per image — lower is better.
    pub weight_traffic: f64,
    /// PE-cycles to complete one image through this layer.
    pub latency: f64,
}

/// Multiplier budget each architecture gets in the comparison.
pub const PE_BUDGET: usize = 1024;
/// Energy units per byte through a global buffer / PE mesh vs a wire.
pub const BUFFER_ENERGY: f64 = 8.0;
/// Output pixels a Distribute PE processes in parallel (DLA-style
/// vectorization across the feature map).
pub const DISTRIBUTE_PIXEL_VEC: usize = 8;

/// Distribute (Fig 2a, DLA-like): activations broadcast from a global
/// buffer to PEs that each own an output channel.
pub fn distribute(layer: &LayerWork) -> Axes {
    let g = &layer.geo;
    // broadcast duplication: PE groups each need the full activation set
    let groups = (PE_BUDGET as f64 / (g.out_c * DISTRIBUTE_PIXEL_VEC) as f64).max(1.0);
    // §III-B1: "only 15% of the activations are used per output channel
    // computation" — the broadcast must be over-provisioned by 1/density
    // to keep the DSPs fed, so effective traffic divides by density.
    let broadcast_waste = 1.0 / layer.density.max(0.05);
    Axes {
        activation_traffic: layer.activation_bytes() * BUFFER_ENERGY * groups * broadcast_waste,
        // every PE decodes its own sparse offsets (§III-B1)
        address_units: PE_BUDGET as f64,
        // idle when out_c (x pixel vector) < PEs
        pe_utilization: ((g.out_c * DISTRIBUTE_PIXEL_VEC) as f64 / PE_BUDGET as f64).min(1.0),
        weight_traffic: layer.nonzero_weights() * 2.0,
        latency: layer.sparse_macs() / PE_BUDGET as f64,
    }
}

/// Tiles Local Transfer can cut an HxW plane into (tiles must be at
/// least a kernel wide).
fn lt_tiles(g: &StageGeometry) -> f64 {
    let side = (g.out_h / g.kh.max(1)).max(1);
    ((side * side) as f64).min(PE_BUDGET as f64)
}

/// Local Transfer (Fig 2b, SCNN-like): activations tiled across a PE
/// array in H and W; halos exchanged with neighbours.
pub fn local_transfer(layer: &LayerWork) -> Axes {
    let g = &layer.geo;
    let tiles = lt_tiles(g);
    // halo exchange per image: each tile trades (k-1)-wide borders
    let halo_elems =
        2.0 * (g.kh.saturating_sub(1) + g.kw.saturating_sub(1)) as f64
            * (g.out_h + g.out_w) as f64
            * tiles.sqrt()
            * g.in_c as f64;
    Axes {
        activation_traffic: halo_elems * 2.0 * BUFFER_ENERGY,
        // per-row address generation across the tile array
        address_units: tiles.sqrt(),
        pe_utilization: tiles / PE_BUDGET as f64,
        // weights multicast across the tile array (quadrant repeaters)
        weight_traffic: layer.nonzero_weights() * 2.0 * 4.0,
        // paper §III-C: LT still uses all multipliers per layer
        latency: layer.sparse_macs() / PE_BUDGET as f64,
    }
}

/// Pipeline (Fig 2c, HPIPE): activations flow stage to stage; weights
/// are re-read from on-chip buffers for every output line.
pub fn pipeline(layer: &LayerWork) -> Axes {
    let g = &layer.geo;
    Axes {
        // activations move exactly once, over dedicated wires
        activation_traffic: layer.activation_bytes(),
        // one shared address unit per stage (the §III-B1 insight)
        address_units: 1.0,
        // multipliers are sized to the layer; only lock-step padding idles
        pe_utilization: (0.6 + 0.4 * layer.density).min(1.0),
        // §III-B3: "it then needs to load all of the weights again to
        // complete the next portion" — once per output line
        weight_traffic: layer.nonzero_weights() * 2.0 * g.out_h as f64,
        // the layer gets ~1/N of the multipliers (N pipelined layers) and
        // pays fill
        latency: layer.sparse_macs() / (PE_BUDGET as f64 / 4.0)
            + (g.kh * g.in_w * g.in_c) as f64 / 64.0,
    }
}

/// Per-axis grade thresholds: value/best (or best/value for
/// higher-is-better) below `excellent` → Excellent, below `good` → Good.
pub fn grade_ratio(ratio: f64, excellent: f64, good: f64) -> &'static str {
    if ratio <= excellent {
        "Excellent"
    } else if ratio <= good {
        "Good"
    } else {
        "Poor"
    }
}

/// Utilization grades on absolute value (the paper's shape-flexibility
/// axis): ≥0.6 Excellent, ≥0.25 Good, else Poor.
pub fn grade_utilization(u: f64) -> &'static str {
    if u >= 0.6 {
        "Excellent"
    } else if u >= 0.25 {
        "Good"
    } else {
        "Poor"
    }
}

/// The ResNet-50 layer suite used by the Table I bench: 3x3 convolutions
/// from each stage — early wide planes to late 7x7 planes (the shapes
/// that expose Local Transfer's weakness), all at 85% sparsity.
pub fn resnet_layer_suite() -> Vec<LayerWork> {
    let mk = |h: usize, w: usize, ci: usize, co: usize, k: usize| LayerWork {
        geo: StageGeometry {
            in_w: w,
            in_c: ci,
            out_w: w,
            out_h: h,
            out_c: co,
            kh: k,
            kw: k,
            stride: 1,
        },
        density: 0.15,
    };
    vec![
        mk(56, 56, 64, 64, 3),   // res2 3x3: big plane, few channels
        mk(28, 28, 128, 128, 3), // res3
        mk(14, 14, 256, 256, 3), // res4
        mk(7, 7, 512, 512, 3),   // res5: tiny plane, many channels
    ]
}

/// Aggregate Table I for the suite: sums traffic/latency, min utilization.
pub struct SuiteAxes {
    pub distribute: Axes,
    pub local_transfer: Axes,
    pub pipeline: Axes,
}

pub fn evaluate_suite(suite: &[LayerWork]) -> SuiteAxes {
    let agg = |f: fn(&LayerWork) -> Axes| -> Axes {
        let mut a = Axes {
            pe_utilization: 1.0,
            ..Default::default()
        };
        for l in suite {
            let x = f(l);
            a.activation_traffic += x.activation_traffic;
            a.address_units = a.address_units.max(x.address_units);
            a.pe_utilization = a.pe_utilization.min(x.pe_utilization);
            a.weight_traffic += x.weight_traffic;
            a.latency += x.latency;
        }
        a
    };
    SuiteAxes {
        distribute: agg(distribute),
        local_transfer: agg(local_transfer),
        pipeline: agg(pipeline),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_wins_locality_and_addressing() {
        let s = evaluate_suite(&resnet_layer_suite());
        assert!(s.pipeline.activation_traffic < s.distribute.activation_traffic);
        assert!(s.pipeline.activation_traffic < s.local_transfer.activation_traffic);
        assert!(s.pipeline.address_units < s.local_transfer.address_units);
        assert!(s.local_transfer.address_units < s.distribute.address_units);
    }

    #[test]
    fn local_transfer_degrades_on_small_planes() {
        let suite = resnet_layer_suite();
        let early = local_transfer(&suite[0]).pe_utilization;
        let late = local_transfer(&suite[3]).pe_utilization;
        // 7x7 plane -> (7/3)^2 = 4 tiles of 1024 PEs: Fig 2b failure case
        assert!(late < early, "late {late} vs early {early}");
        assert!(late < 0.01, "late-plane PE utilization {late}");
        assert_eq!(grade_utilization(late), "Poor");
    }

    #[test]
    fn distribute_duplicates_broadcast() {
        let suite = resnet_layer_suite();
        // few output channels -> many PE groups -> duplicated broadcast
        let few_co = distribute(&suite[0]);
        let many_co = distribute(&suite[3]);
        let per_byte_few = few_co.activation_traffic / suite[0].activation_bytes();
        let per_byte_many = many_co.activation_traffic / suite[3].activation_bytes();
        assert!(per_byte_few >= per_byte_many);
        // duplication x broadcast-waste make it far worse than a plain
        // buffer round trip
        assert!(per_byte_few > BUFFER_ENERGY * 4.0, "no duplication/waste modeled");
    }

    #[test]
    fn pipeline_pays_weight_bandwidth() {
        for layer in &resnet_layer_suite() {
            let p = pipeline(layer);
            let d = distribute(layer);
            let lt = local_transfer(layer);
            assert!(p.weight_traffic > 2.0 * d.weight_traffic);
            assert!(p.weight_traffic > lt.weight_traffic);
        }
    }

    #[test]
    fn latency_ordering_matches_paper() {
        // Distribute/LT "Excellent", Pipeline "Good" (worse but close).
        let s = evaluate_suite(&resnet_layer_suite());
        assert!(s.pipeline.latency > s.distribute.latency);
        assert!(s.pipeline.latency < s.distribute.latency * 8.0);
        assert!((s.local_transfer.latency - s.distribute.latency).abs() < 1e-6);
    }

    #[test]
    fn suite_grades_match_table1() {
        let s = evaluate_suite(&resnet_layer_suite());
        let best_act = s.pipeline.activation_traffic;
        assert_eq!(
            grade_ratio(s.distribute.activation_traffic / best_act, 2.0, 50.0),
            "Poor"
        );
        assert_eq!(
            grade_ratio(s.local_transfer.activation_traffic / best_act, 2.0, 50.0),
            "Good"
        );
        assert_eq!(grade_ratio(1.0, 2.0, 50.0), "Excellent");
        // weight bandwidth: Pipeline Poor, Distribute Excellent, LT Good
        let best_w = s.distribute.weight_traffic;
        assert_eq!(grade_ratio(s.pipeline.weight_traffic / best_w, 2.0, 8.0), "Poor");
        assert_eq!(grade_ratio(s.local_transfer.weight_traffic / best_w, 2.0, 8.0), "Good");
        // shape flexibility: D Good, LT Poor, P Excellent
        assert_eq!(grade_utilization(s.distribute.pe_utilization), "Good");
        assert_eq!(grade_utilization(s.local_transfer.pe_utilization), "Poor");
        assert_eq!(grade_utilization(s.pipeline.pe_utilization), "Excellent");
    }

    #[test]
    fn grade_helpers() {
        assert_eq!(grade_ratio(1.0, 2.0, 8.0), "Excellent");
        assert_eq!(grade_ratio(5.0, 2.0, 8.0), "Good");
        assert_eq!(grade_ratio(100.0, 2.0, 8.0), "Poor");
        assert_eq!(grade_utilization(0.7), "Excellent");
        assert_eq!(grade_utilization(0.3), "Good");
        assert_eq!(grade_utilization(0.01), "Poor");
    }
}
