//! Graph transformation passes of the HPIPE network compiler (§IV).
//!
//! "Our compiler first attempts to merge all of the batch normalization
//! operations into convolution and bias operations. [...] We run a series
//! of graph transformations that break batch normalizations into an
//! addition and a multiplication and then swap the execution order of
//! certain operations so that they can be merged with operations that
//! were not initially neighbours."
//!
//! The pass pipeline implemented here:
//!   1. [`split_batch_norms`] — `FusedBatchNorm` → per-channel `Mul` + `AddC`
//!      with precomputed inference-time constants.
//!   2. Fixpoint of local rewrites ([`fold_step`]):
//!        * fold `Mul` backward into the producer conv's weights
//!          (per-output-channel) and any interposed `BiasAdd`;
//!        * fold `AddC` backward into the producer conv's `BiasAdd`
//!          (inserting one if the conv has none);
//!        * swap `Mul`/`AddC` forward past `MaxPool` (valid since the
//!          scales are positive: max(a·x+b) = a·max(x)+b);
//!        * swap `Mul` forward past `Pad` (zero-pad commutes with scaling)
//!          and past `Relu`/`Relu6`* (positive scale);
//!        * fold `Mul` forward into a consumer conv's weights
//!          (per-input-channel).
//!   3. [`merge_pads`] — standalone `Pad` nodes merge into the consumer
//!      convolution/pool's explicit-padding attribute.
//!   4. Dead-node elimination.
//!
//! *`Relu6` swap rewrites the clamp bound: relu6(a·x) = a·min(relu(x),6/a),
//! which is no longer a plain Relu6 — so like the paper we only move `Mul`
//! past plain `Relu`, and fold V2's pre-Relu6 BNs backward instead.
//!
//! Equivalence with the original graph is established by [`equiv`]'s
//! random-input checker; `verify=true` in [`optimize`] runs it inline
//! (the analog of the paper re-running the dumped graphdef through
//! TensorFlow to validate accuracy is unchanged).

pub mod equiv;

use crate::graph::{Graph, Node, Op, Padding, Tensor};
use std::collections::HashMap;

/// Statistics from a transform run (used by tests and reports).
#[derive(Debug, Default, Clone)]
pub struct TransformLog {
    pub batch_norms_split: usize,
    pub muls_folded_backward: usize,
    pub muls_folded_forward: usize,
    pub addcs_folded: usize,
    pub swaps_past_maxpool: usize,
    pub swaps_past_pad: usize,
    pub swaps_past_relu: usize,
    pub pads_merged: usize,
    pub biases_inserted: usize,
}

impl TransformLog {
    /// True iff every BN was eliminated (the paper's headline claim for
    /// ResNet-50 / MobileNet V1 / V2).
    pub fn all_bns_folded(&self, graph: &Graph) -> bool {
        !graph
            .nodes
            .iter()
            .any(|n| matches!(n.op, Op::FusedBatchNorm { .. } | Op::Mul | Op::AddC))
    }
}

/// Run the full §IV pipeline. Panics only on internal invariant
/// violations; structural errors surface through `Graph::validate`.
pub fn optimize(graph: &Graph) -> (Graph, TransformLog) {
    let mut g = graph.clone();
    let mut log = TransformLog::default();
    split_batch_norms(&mut g, &mut log);
    // Fixpoint the local rewrites; each iteration applies at most one
    // rewrite per node, so the bound is generous.
    for _ in 0..10 * g.len() {
        if !fold_step(&mut g, &mut log) {
            break;
        }
    }
    merge_pads(&mut g, &mut log);
    g.prune_dead();
    (g, log)
}

/// Pass 1: split every FusedBatchNorm into Mul(a) then AddC(b) where
/// a = γ/√(σ²+ε), b = β − μ·a (the standard inference-time folding).
pub fn split_batch_norms(g: &mut Graph, log: &mut TransformLog) {
    let bn_nodes: Vec<String> = g
        .nodes
        .iter()
        .filter(|n| matches!(n.op, Op::FusedBatchNorm { .. }))
        .map(|n| n.name.clone())
        .collect();
    for name in bn_nodes {
        let (x_in, a, b) = {
            let n = g.get(&name).unwrap();
            let eps = match n.op {
                Op::FusedBatchNorm { epsilon } => epsilon,
                _ => unreachable!(),
            };
            let fetch = |k: usize| -> &Tensor {
                g.get(&n.inputs[k])
                    .expect("bn param")
                    .value
                    .as_ref()
                    .expect("bn param const")
            };
            let (scale, offset, mean, var) = (fetch(1), fetch(2), fetch(3), fetch(4));
            let a: Vec<f32> = scale
                .data
                .iter()
                .zip(&var.data)
                .map(|(&s, &v)| s / (v + eps).sqrt())
                .collect();
            let b: Vec<f32> = offset
                .data
                .iter()
                .zip(mean.data.iter().zip(&a))
                .map(|(&o, (&m, &av))| o - m * av)
                .collect();
            let c = a.len();
            (
                n.inputs[0].clone(),
                Tensor::from_vec(&[c], a),
                Tensor::from_vec(&[c], b),
            )
        };
        let a_name = g.constant(&format!("{name}/fold_scale"), a);
        let b_name = g.constant(&format!("{name}/fold_offset"), b);
        let mul_name = g.op(&format!("{name}/mul"), Op::Mul, &[&x_in, &a_name]);
        // Rewrite the BN node in place into the AddC so consumers keep
        // their input names.
        let node = g.get_mut(&name).unwrap();
        node.op = Op::AddC;
        node.inputs = vec![mul_name, b_name];
        log.batch_norms_split += 1;
    }
}

/// One fixpoint iteration of the local Mul/AddC rewrites. Returns true if
/// anything changed.
///
/// Direction policy (avoids swap ping-pong): a `Mul`/`AddC` first tries to
/// reach its *producing* convolution — folding directly when adjacent
/// (through at most a `BiasAdd`), otherwise swapping one step backward
/// past an op it commutes with (`MaxPool` for both; `Pad`/`Relu` for `Mul`
/// only, valid because BN scales are positive) whenever the backward chain
/// provably ends at a conv. Only when no backward path exists does a `Mul`
/// fold *forward* into its consumer conv's input channels.
pub fn fold_step(g: &mut Graph, log: &mut TransformLog) -> bool {
    let consumers = g.consumers();
    let single_consumer = |name: &str| -> Option<String> {
        match consumers.get(name).map(|v| v.as_slice()) {
            Some([only]) => Some(only.clone()),
            _ => None,
        }
    };

    // Scan against an immutable snapshot and apply the first applicable
    // rewrite (optimize() fixpoints, so one rewrite per call is fine).
    for i in 0..g.nodes.len() {
        let node = g.nodes[i].clone();
        let is_mul = matches!(node.op, Op::Mul);
        let is_addc = matches!(node.op, Op::AddC);
        // skip non-candidates and nodes already bypassed this round
        // (bypass() clears inputs; prune_dead runs after the fixpoint)
        if (!is_mul && !is_addc) || node.inputs.is_empty() {
            continue;
        }
        let producer_name = node.inputs[0].clone();

        // --- adjacent backward fold (through at most a BiasAdd) ---
        if let Some(conv_name) =
            adjacent_conv_backward(g, &producer_name, &consumers, &node.name)
        {
            if is_mul {
                fold_mul_backward(g, &node, &conv_name);
                log.muls_folded_backward += 1;
            } else {
                fold_addc_backward(g, &node, &conv_name, log);
                log.addcs_folded += 1;
            }
            return true;
        }

        // --- backward swap one step, if the chain provably reaches a conv ---
        if reaches_conv_backward(g, &producer_name, &consumers, &node.name, is_mul) {
            let prod = g.get(&producer_name).unwrap().clone();
            let ok = match prod.op {
                Op::MaxPool { .. } => {
                    log.swaps_past_maxpool += 1;
                    true
                }
                Op::Pad { .. } if is_mul => {
                    log.swaps_past_pad += 1;
                    true
                }
                Op::Relu if is_mul => {
                    log.swaps_past_relu += 1;
                    true
                }
                _ => false,
            };
            if ok {
                swap_with_producer(g, &node.name, &producer_name);
                return true;
            }
        }

        // --- forward fold (Mul only): consumer conv scales input channels ---
        if is_mul {
            if let Some(c) = single_consumer(&node.name) {
                let cons = g.get(&c).unwrap().clone();
                match cons.op {
                    Op::Conv2D { .. } | Op::DepthwiseConv2d { .. } | Op::MatMul
                        if cons.inputs[0] == node.name =>
                    {
                        fold_mul_forward(g, &node, &cons.name);
                        log.muls_folded_forward += 1;
                        return true;
                    }
                    // forward swaps toward a downstream conv, only when
                    // there is no backward conv at all (checked above)
                    Op::Relu => {
                        swap_with_consumer(g, &node.name, &c);
                        log.swaps_past_relu += 1;
                        return true;
                    }
                    Op::MaxPool { .. } => {
                        swap_with_consumer(g, &node.name, &c);
                        log.swaps_past_maxpool += 1;
                        return true;
                    }
                    Op::Pad { .. } => {
                        swap_with_consumer(g, &node.name, &c);
                        log.swaps_past_pad += 1;
                        return true;
                    }
                    _ => {}
                }
            }
        }
    }
    false
}

/// Is `start` a conv/matmul, or a BiasAdd directly on one, with every hop
/// single-consumer? Returns the conv name for immediate folding.
fn adjacent_conv_backward(
    g: &Graph,
    start: &str,
    consumers: &HashMap<String, Vec<String>>,
    expected_reader: &str,
) -> Option<String> {
    let mut cur = start.to_string();
    let mut reader = expected_reader.to_string();
    for _ in 0..2 {
        // the producer must feed only `reader`
        match consumers.get(&cur).map(|v| v.as_slice()) {
            Some([only]) if *only == reader => {}
            _ => return None,
        }
        let n = g.get(&cur)?;
        match n.op {
            Op::Conv2D { .. } | Op::DepthwiseConv2d { .. } | Op::MatMul => {
                return Some(cur);
            }
            Op::BiasAdd => {
                reader = cur.clone();
                cur = n.inputs[0].clone();
            }
            _ => return None,
        }
    }
    None
}

/// Can a Mul (or AddC when `is_mul` is false) reach a producing conv by
/// swapping backward through ops it commutes with? Walks the chain
/// conv <- {BiasAdd, MaxPool, Pad*, Relu*} <- start (single-consumer
/// hops; * Mul-only) without mutating anything.
fn reaches_conv_backward(
    g: &Graph,
    start: &str,
    consumers: &HashMap<String, Vec<String>>,
    expected_reader: &str,
    is_mul: bool,
) -> bool {
    let mut cur = start.to_string();
    let mut reader = expected_reader.to_string();
    for _ in 0..g.len() {
        match consumers.get(&cur).map(|v| v.as_slice()) {
            Some([only]) if *only == reader => {}
            _ => return false,
        }
        let Some(n) = g.get(&cur) else { return false };
        match n.op {
            Op::Conv2D { .. } | Op::DepthwiseConv2d { .. } | Op::MatMul => return true,
            Op::BiasAdd | Op::MaxPool { .. } => {}
            Op::Pad { .. } | Op::Relu if is_mul => {}
            _ => return false,
        }
        reader = cur.clone();
        cur = n.inputs[0].clone();
    }
    false
}

/// Scale per-output-channel: conv weights (and any BiasAdd between conv
/// and the Mul) are multiplied by a; the Mul node is then bypassed.
fn fold_mul_backward(g: &mut Graph, mul: &Node, conv_name: &str) {
    let a = g
        .get(&mul.inputs[1])
        .unwrap()
        .value
        .clone()
        .expect("mul const");
    // scale conv weights along the *output* dimension
    let wname = g.get(conv_name).unwrap().inputs[1].clone();
    let depthwise = matches!(g.get(conv_name).unwrap().op, Op::DepthwiseConv2d { .. });
    {
        let w = g.get_mut(&wname).unwrap().value.as_mut().unwrap();
        scale_out_channels(w, &a.data, depthwise);
    }
    // scale the interposed BiasAdd too, if the chain went through one
    let producer = g.get(&mul.inputs[0]).unwrap().clone();
    if matches!(producer.op, Op::BiasAdd) {
        let bname = producer.inputs[1].clone();
        let b = g.get_mut(&bname).unwrap().value.as_mut().unwrap();
        for (v, &s) in b.data.iter_mut().zip(&a.data) {
            *v *= s;
        }
    }
    bypass(g, &mul.name);
}

/// Scale per-input-channel of the consumer conv's weights.
fn fold_mul_forward(g: &mut Graph, mul: &Node, conv_name: &str) {
    let a = g
        .get(&mul.inputs[1])
        .unwrap()
        .value
        .clone()
        .expect("mul const");
    let wname = g.get(conv_name).unwrap().inputs[1].clone();
    let op = g.get(conv_name).unwrap().op.clone();
    {
        let w = g.get_mut(&wname).unwrap().value.as_mut().unwrap();
        match op {
            Op::Conv2D { .. } | Op::DepthwiseConv2d { .. } => {
                // HWIO / HWIM: dim 2 is the input channel
                let (kh, kw, ci, co) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
                for k in 0..kh * kw {
                    for c in 0..ci {
                        for o in 0..co {
                            w.data[(k * ci + c) * co + o] *= a.data[c];
                        }
                    }
                }
            }
            Op::MatMul => {
                let (ci, co) = (w.shape[0], w.shape[1]);
                for c in 0..ci {
                    for o in 0..co {
                        w.data[c * co + o] *= a.data[c];
                    }
                }
            }
            _ => unreachable!(),
        }
    }
    // conv now reads the Mul's input directly
    let mul_input = mul.inputs[0].clone();
    let conv = g.get_mut(conv_name).unwrap();
    conv.inputs[0] = mul_input;
}

/// Add the AddC constant into the producer conv's bias, creating a
/// BiasAdd if the conv doesn't have one.
fn fold_addc_backward(g: &mut Graph, addc: &Node, conv_name: &str, log: &mut TransformLog) {
    let b = g
        .get(&addc.inputs[1])
        .unwrap()
        .value
        .clone()
        .expect("addc const");
    let producer = g.get(&addc.inputs[0]).unwrap().clone();
    if matches!(producer.op, Op::BiasAdd) {
        let bname = producer.inputs[1].clone();
        let bias = g.get_mut(&bname).unwrap().value.as_mut().unwrap();
        for (v, &x) in bias.data.iter_mut().zip(&b.data) {
            *v += x;
        }
        bypass(g, &addc.name);
    } else {
        // insert a BiasAdd directly after the conv, then bypass the AddC
        let bias_const = g.constant(&format!("{conv_name}/folded_bias"), b);
        let bias_node = g.op(
            &format!("{conv_name}/folded_biasadd"),
            Op::BiasAdd,
            &[conv_name, &bias_const],
        );
        log.biases_inserted += 1;
        // the AddC read the conv directly; everything that read the AddC
        // now reads the new BiasAdd
        rewire_consumers(g, &addc.name, &bias_node);
        // drop the AddC's edge so prune_dead removes it
        g.get_mut(&addc.name).unwrap().inputs.clear();
    }
}

/// Pass 3: merge standalone Pad nodes into their consumer conv/pool.
pub fn merge_pads(g: &mut Graph, log: &mut TransformLog) {
    let consumers = g.consumers();
    let pads: Vec<String> = g
        .nodes
        .iter()
        .filter(|n| matches!(n.op, Op::Pad { .. }))
        .map(|n| n.name.clone())
        .collect();
    for pname in pads {
        let Some(cs) = consumers.get(&pname) else { continue };
        // every consumer must be able to absorb the padding
        let absorbable = cs.iter().all(|c| {
            matches!(
                g.get(c).unwrap().op,
                Op::Conv2D { .. } | Op::DepthwiseConv2d { .. } | Op::MaxPool { .. }
            )
        });
        if !absorbable || cs.is_empty() {
            continue;
        }
        let pad_node = g.get(&pname).unwrap().clone();
        let (pt, pb, pl, pr) = match pad_node.op {
            Op::Pad { pads } => pads,
            _ => unreachable!(),
        };
        for c in cs {
            let cons = g.get_mut(c).unwrap();
            let combine = |p: Padding| -> Option<Padding> {
                match p {
                    Padding::Valid => Some(Padding::Explicit(pt, pb, pl, pr)),
                    Padding::Explicit(t, b, l, r) => {
                        Some(Padding::Explicit(t + pt, b + pb, l + pl, r + pr))
                    }
                    // SAME after an explicit pad would change semantics
                    Padding::Same => None,
                }
            };
            let new_op = match cons.op.clone() {
                Op::Conv2D { stride, padding } => {
                    combine(padding).map(|p| Op::Conv2D { stride, padding: p })
                }
                Op::DepthwiseConv2d { stride, padding } => {
                    combine(padding).map(|p| Op::DepthwiseConv2d { stride, padding: p })
                }
                Op::MaxPool { ksize, stride, padding } => {
                    combine(padding).map(|p| Op::MaxPool { ksize, stride, padding: p })
                }
                _ => None,
            };
            if let Some(op) = new_op {
                cons.op = op;
                cons.inputs[0] = pad_node.inputs[0].clone();
            } else {
                // couldn't merge for this consumer; leave the Pad in place
                continue;
            }
        }
        log.pads_merged += 1;
    }
    g.prune_dead();
}

// ---------------- surgery helpers ----------------

/// Make all consumers of `from` read `to` instead; also fix outputs.
fn rewire_consumers(g: &mut Graph, from: &str, to: &str) {
    for n in g.nodes.iter_mut() {
        if n.name == to {
            continue;
        }
        for i in n.inputs.iter_mut() {
            if i == from {
                *i = to.to_string();
            }
        }
    }
    for o in g.outputs.iter_mut() {
        if o == from {
            *o = to.to_string();
        }
    }
}

/// Remove a single-input elementwise node from the graph by rewiring its
/// consumers to its first input.
fn bypass(g: &mut Graph, name: &str) {
    let input = g.get(name).unwrap().inputs[0].clone();
    rewire_consumers(g, name, &input);
    g.get_mut(name).unwrap().inputs.clear();
}

/// Swap an elementwise node with its single-consumer producer:
/// `x -> prod -> elem -> ...` becomes `x -> elem -> prod -> ...`.
fn swap_with_producer(g: &mut Graph, elem: &str, prod: &str) {
    let x = g.get(prod).unwrap().inputs[0].clone();
    // everything that read elem now reads prod (prod's own input is x,
    // untouched by this rewrite)
    rewire_consumers(g, elem, prod);
    g.get_mut(elem).unwrap().inputs[0] = x;
    g.get_mut(prod).unwrap().inputs[0] = elem.to_string();
}

/// Swap an elementwise node with its single consumer:
/// `x -> elem -> cons -> ...` becomes `x -> cons -> elem -> ...`.
fn swap_with_consumer(g: &mut Graph, elem: &str, cons: &str) {
    let x = g.get(elem).unwrap().inputs[0].clone();
    // consumers of `cons` should read `elem`
    rewire_consumers(g, cons, elem);
    // cons reads x
    g.get_mut(cons).unwrap().inputs[0] = x;
    // elem reads cons (rewire_consumers skipped fixing elem's own input;
    // set it explicitly)
    g.get_mut(elem).unwrap().inputs[0] = cons.to_string();
}

/// Multiply conv weights per output channel; for depthwise the "output"
/// index is (ci, m) flattened.
fn scale_out_channels(w: &mut Tensor, a: &[f32], depthwise: bool) {
    if w.shape.len() == 2 {
        // MatMul weights (ci, co)
        let co = w.shape[1];
        for (i, v) in w.data.iter_mut().enumerate() {
            *v *= a[i % co];
        }
        return;
    }
    let (kh, kw, ci, m) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    if depthwise {
        for k in 0..kh * kw {
            for c in 0..ci {
                for j in 0..m {
                    w.data[(k * ci + c) * m + j] *= a[c * m + j];
                }
            }
        }
    } else {
        for (i, v) in w.data.iter_mut().enumerate() {
            *v *= a[i % m];
        }
        let _ = (kh, kw, ci);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{mobilenet_v1, mobilenet_v2, resnet50, NetConfig};
    use crate::util::Rng;

    fn count_ops(g: &Graph, pred: impl Fn(&Op) -> bool) -> usize {
        g.nodes.iter().filter(|n| pred(&n.op)).count()
    }

    #[test]
    fn resnet50_all_bns_fold() {
        let g = resnet50(NetConfig::test_scale());
        let before_bn = count_ops(&g, |o| matches!(o, Op::FusedBatchNorm { .. }));
        assert_eq!(before_bn, 53);
        let (opt, log) = optimize(&g);
        assert!(log.all_bns_folded(&opt), "log: {log:?}");
        assert_eq!(log.batch_norms_split, 53);
        // conv1 had no bias — one must have been inserted for its BN
        assert!(log.biases_inserted >= 1);
        opt.validate().unwrap();
    }

    #[test]
    fn mobilenets_all_bns_fold() {
        for (name, g) in [
            ("v1", mobilenet_v1(NetConfig::test_scale())),
            ("v2", mobilenet_v2(NetConfig::test_scale())),
        ] {
            let (opt, log) = optimize(&g);
            assert!(log.all_bns_folded(&opt), "{name}: {log:?}");
            opt.validate().unwrap();
        }
    }

    #[test]
    fn resnet50_pad_merged_into_conv1() {
        let g = resnet50(NetConfig::test_scale());
        let (opt, log) = optimize(&g);
        assert!(log.pads_merged >= 1);
        assert!(opt.get("conv1_pad").is_none(), "pad node should be gone");
        match opt.get("conv1").unwrap().op {
            Op::Conv2D { padding: Padding::Explicit(3, 3, 3, 3), .. } => {}
            ref op => panic!("conv1 padding not merged: {op:?}"),
        }
    }

    #[test]
    fn optimize_preserves_resnet_outputs() {
        let g = resnet50(NetConfig::test_scale());
        let (opt, _) = optimize(&g);
        equiv::assert_equivalent(&g, &opt, 3, 1e-3).unwrap();
    }

    #[test]
    fn optimize_preserves_mobilenet_v2_outputs() {
        let g = mobilenet_v2(NetConfig::test_scale());
        let (opt, _) = optimize(&g);
        equiv::assert_equivalent(&g, &opt, 3, 1e-3).unwrap();
    }

    #[test]
    fn bn_after_maxpool_swaps_and_folds() {
        // The paper's motivating non-adjacent case: conv -> maxpool -> BN.
        // After splitting, Mul and AddC must swap *backward* past the
        // MaxPool (valid for positive scales) and fold into the conv.
        let mut b = crate::nets::NetBuilder::new(9);
        let x = b.input("input", 8, 8, 4);
        let c1 = b.conv("c1", &x, 3, 4, 8, 1, Padding::Same);
        let p = b.g.op(
            "pool",
            Op::MaxPool { ksize: (2, 2), stride: (2, 2), padding: Padding::Valid },
            &[&c1],
        );
        let bn = b.bn("bn", &p, 8);
        let c2 = b.conv("c2", &bn, 1, 8, 4, 1, Padding::Same);
        b.g.outputs = vec![c2];
        let g = b.g;
        let (opt, log) = optimize(&g);
        assert!(log.all_bns_folded(&opt), "{log:?}");
        // AddC after the pool folds backward through... no — the producer
        // is MaxPool, so the Mul folds FORWARD into c2 and the AddC has
        // nowhere to go backward; it needs the forward path too. Verify
        // numerically regardless:
        equiv::assert_equivalent(&g, &opt, 4, 1e-4).unwrap();
    }

    #[test]
    fn mul_moves_past_relu() {
        // conv -> relu -> BN(-ish Mul only) -> conv : the Mul must cross
        // the relu forward and fold into the second conv.
        let mut g = Graph::new();
        let mut rng = Rng::new(11);
        g.op("input", Op::Placeholder { shape: vec![1, 6, 6, 2] }, &[]);
        g.constant("w1", Tensor::randn(&[3, 3, 2, 4], &mut rng, 0.4));
        g.op(
            "c1",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["input", "w1"],
        );
        g.op("relu", Op::Relu, &["c1"]);
        let scale = Tensor::from_vec(&[4], vec![0.5, 2.0, 1.5, 0.25]);
        g.constant("a", scale);
        g.op("mul", Op::Mul, &["relu", "a"]);
        g.constant("w2", Tensor::randn(&[1, 1, 4, 3], &mut rng, 0.4));
        g.op(
            "c2",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["mul", "w2"],
        );
        g.outputs = vec!["c2".into()];

        let mut log = TransformLog::default();
        let mut opt = g.clone();
        for _ in 0..50 {
            if !fold_step(&mut opt, &mut log) {
                break;
            }
        }
        opt.prune_dead();
        // The Mul folds backward into c1 (single-consumer chain through
        // relu is not allowed backwards — backward folding crosses only
        // BiasAdd — so it must have swapped past relu then folded forward).
        assert_eq!(count_ops(&opt, |o| matches!(o, Op::Mul)), 0);
        assert!(log.swaps_past_relu >= 1 || log.muls_folded_backward >= 1);
        equiv::assert_equivalent(&g, &opt, 4, 1e-4).unwrap();
    }

    #[test]
    fn fold_is_idempotent() {
        let g = resnet50(NetConfig::test_scale());
        let (opt1, _) = optimize(&g);
        let (opt2, log2) = optimize(&opt1);
        assert_eq!(log2.batch_norms_split, 0);
        assert_eq!(opt1.len(), opt2.len());
    }

    #[test]
    fn matmul_bn_folds() {
        // GAP -> MatMul -> BN-ish chain (seen in some classifier heads)
        let mut b = crate::nets::NetBuilder::new(13);
        let x = b.input("input", 4, 4, 6);
        let gap = b.g.op("gap", Op::Mean, &[&x]);
        let std = 0.5;
        let w = Tensor::randn(&[6, 5], &mut b.rng, std);
        b.g.constant("w", w);
        let mm = b.g.op("fc", Op::MatMul, &[&gap, "w"]);
        let bn = b.bn("fc_bn", &mm, 5);
        b.g.outputs = vec![bn];
        let g = b.g;
        let (opt, log) = optimize(&g);
        assert!(log.all_bns_folded(&opt), "{log:?}");
        equiv::assert_equivalent(&g, &opt, 4, 1e-4).unwrap();
    }
}
