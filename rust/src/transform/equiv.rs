//! Numerical equivalence checking between graph versions.
//!
//! The paper validates its transforms by re-running the dumped graphdef
//! through TensorFlow and confirming ImageNet accuracy is unchanged. Our
//! analog: run both graphs through the reference interpreter on random
//! inputs and require the outputs to match to tolerance.

use crate::graph::{Graph, Op, Tensor};
use crate::interp;
use crate::util::Rng;
use std::collections::BTreeMap;

/// Compare two graphs on `trials` random inputs. Returns Err with a
/// description of the first mismatch. Tolerance is relative to the output
/// magnitude (transforms reassociate float math, so exact equality is not
/// expected).
pub fn assert_equivalent(
    a: &Graph,
    b: &Graph,
    trials: usize,
    tol: f32,
) -> Result<(), String> {
    let feeds_spec: Vec<(String, Vec<usize>)> = a
        .nodes
        .iter()
        .filter_map(|n| match &n.op {
            Op::Placeholder { shape } => Some((n.name.clone(), shape.clone())),
            _ => None,
        })
        .collect();
    if feeds_spec.is_empty() {
        return Err("graph has no placeholders".into());
    }
    let mut rng = Rng::new(0xE9);
    for t in 0..trials {
        let mut feeds = BTreeMap::new();
        for (name, shape) in &feeds_spec {
            feeds.insert(name.clone(), Tensor::randn(shape, &mut rng, 1.0));
        }
        let oa = interp::run_outputs(a, &feeds).map_err(|e| format!("graph A: {e}"))?;
        let ob = interp::run_outputs(b, &feeds).map_err(|e| format!("graph B: {e}"))?;
        if oa.len() != ob.len() {
            return Err(format!("output count {} vs {}", oa.len(), ob.len()));
        }
        for (k, (ta, tb)) in oa.iter().zip(&ob).enumerate() {
            if ta.shape != tb.shape {
                return Err(format!(
                    "trial {t} output {k}: shape {:?} vs {:?}",
                    ta.shape, tb.shape
                ));
            }
            let scale = ta.max_abs().max(1e-3);
            for (i, (&x, &y)) in ta.data.iter().zip(&tb.data).enumerate() {
                if (x - y).abs() > tol * scale {
                    return Err(format!(
                        "trial {t} output {k}[{i}]: {x} vs {y} (scale {scale})"
                    ));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Padding;

    fn conv_graph(scale: f32) -> Graph {
        let mut g = Graph::new();
        let mut rng = Rng::new(77);
        g.op("input", Op::Placeholder { shape: vec![1, 4, 4, 2] }, &[]);
        let mut w = Tensor::randn(&[3, 3, 2, 3], &mut rng, 0.5);
        for v in w.data.iter_mut() {
            *v *= scale;
        }
        g.constant("w", w);
        g.op(
            "conv",
            Op::Conv2D { stride: (1, 1), padding: Padding::Same },
            &["input", "w"],
        );
        g.outputs = vec!["conv".into()];
        g
    }

    #[test]
    fn identical_graphs_are_equivalent() {
        let g = conv_graph(1.0);
        assert_equivalent(&g, &g.clone(), 3, 1e-6).unwrap();
    }

    #[test]
    fn different_weights_are_not() {
        let a = conv_graph(1.0);
        let b = conv_graph(1.01);
        assert!(assert_equivalent(&a, &b, 1, 1e-6).is_err());
    }

    #[test]
    fn no_placeholder_is_error() {
        let mut g = Graph::new();
        g.constant("c", Tensor::scalar(1.0));
        g.outputs = vec!["c".into()];
        assert!(assert_equivalent(&g, &g.clone(), 1, 1e-6).is_err());
    }
}
