//! The throughput balancer (§IV).
//!
//! "With an analytic model that estimates the throughput of a convolution
//! operation, given this parameter, we can loop over the slowest
//! operations and increment n_channel_splits until we hit the DSP
//! Target."
//!
//! The loop: find the stage with the highest cycle count; if it is a
//! compute stage below its unroll cap and the DSP budget allows the
//! increment, raise its `n_channel_splits` and re-estimate with the
//! partition-aware model. Stop when (a) the DSP target is reached,
//! (b) the bottleneck has run out of unroll (the paper's MobileNet-V2
//! "we ran out of input channels to unroll" case), or (c) the bottleneck
//! is a non-compute stage that no DSP can speed up.
//!
//! Splits step through divisor-friendly values (+25% rounded up) rather
//! than +1 so full ResNet-50 balances in milliseconds — the paper quotes
//! "a few seconds" for its Python implementation.

use super::throughput::{stage_cycles, WeightSummary};
use super::{stage_mults, stage_resources, CompileOptions, StagePlan};

/// Outcome of a balance run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StopReason {
    DspTargetReached,
    BottleneckAtUnrollCap,
    BottleneckNotCompute,
    NoProgress,
}

/// Balance stage splits toward the DSP target in place. Returns the stop
/// reason and the number of increments applied.
pub fn balance(
    stages: &mut [StagePlan],
    summaries: &[Option<WeightSummary>],
    opts: &CompileOptions,
) -> (StopReason, usize) {
    assert_eq!(stages.len(), summaries.len());
    let mut total_dsps: usize = stages.iter().map(|s| s.resources.dsps).sum();
    let mut increments = 0usize;
    // Safety bound: every stage can be incremented at most ~log(cap)/log(1.25)
    // times; 64 steps per stage is far beyond that.
    let max_iters = stages.len() * 64;

    for _ in 0..max_iters {
        // slowest stage
        let (bi, _) = match stages
            .iter()
            .enumerate()
            .max_by_key(|(_, s)| s.cycles)
        {
            Some(x) => x,
            None => return (StopReason::NoProgress, increments),
        };
        let st = &stages[bi];
        if !st.is_compute() {
            return (StopReason::BottleneckNotCompute, increments);
        }
        if st.splits >= st.unroll_cap {
            return (StopReason::BottleneckAtUnrollCap, increments);
        }
        // next splits value: +25% (at least +1), clamped to the cap
        let next = ((st.splits * 5).div_ceil(4)).max(st.splits + 1).min(st.unroll_cap);

        // provisional new cost — one padded_both pass yields both the
        // cycles and the buffer entries (perf: was two passes)
        let new_mults = stage_mults(&st.op, &st.geo, next);
        let padded = summaries[bi].as_ref().map(|s| s.padded_both(next));
        let new_entries = padded.map(|(_, e)| e).unwrap_or(0);
        let new_res = stage_resources(
            opts,
            &st.op,
            &st.geo,
            next,
            new_mults,
            new_entries,
            st.buffer_lines,
        );
        let new_total = total_dsps - st.resources.dsps + new_res.dsps;
        if new_total > opts.dsp_target {
            return (StopReason::DspTargetReached, increments);
        }
        let new_cycles = if let (Some((cyc, _)), true) = (padded, opts.partition_aware) {
            // reuse the pass above for compute stages under the
            // partition-aware model (identical to stage_cycles)
            match st.op {
                crate::graph::Op::Conv2D { .. } => {
                    st.geo.out_h as u64 * (cyc + super::throughput::LINE_OVERHEAD)
                        + next as u64 / 2
                }
                crate::graph::Op::MatMul => {
                    cyc + super::throughput::LINE_OVERHEAD + next as u64 / 2
                }
                _ => stage_cycles(&st.op, &st.geo, next, summaries[bi].as_ref(), true),
            }
        } else {
            stage_cycles(
                &st.op,
                &st.geo,
                next,
                summaries[bi].as_ref(),
                opts.partition_aware,
            )
        };
        let st = &mut stages[bi];
        total_dsps = new_total;
        st.splits = next;
        st.mults = new_mults;
        st.weight_entries = new_entries;
        st.resources = new_res;
        // Partition padding can make an increment useless (same max
        // stream); accept it anyway — the DSP cost was paid and the next
        // iteration will keep pushing this stage while it bottlenecks.
        st.cycles = new_cycles;
        increments += 1;
    }
    (StopReason::NoProgress, increments)
}

/// Imbalance metric used by Fig 3's reproduction: the ratio of the
/// slowest stage to the median compute stage (paper: "nearly all of the
/// layers have throughput within 10% of each other").
pub fn imbalance(stages: &[StagePlan]) -> f64 {
    let mut compute: Vec<u64> = stages
        .iter()
        .filter(|s| s.is_compute())
        .map(|s| s.cycles)
        .collect();
    if compute.is_empty() {
        return 1.0;
    }
    compute.sort_unstable();
    let max = *compute.last().unwrap() as f64;
    let median = compute[compute.len() / 2] as f64;
    max / median.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::S10_2800;
    use crate::compile::plan_stages;
    use crate::nets::NetConfig;
    use crate::sparsity::prune_graph;
    use crate::transform::optimize;

    fn planned(
        net: &str,
        dsp_target: usize,
        sparsity: f64,
    ) -> (Vec<StagePlan>, Vec<Option<WeightSummary>>, CompileOptions) {
        let mut g = crate::nets::build_named(net, NetConfig::test_scale()).unwrap();
        if sparsity > 0.0 {
            prune_graph(&mut g, sparsity);
        }
        let (g, _) = optimize(&g);
        let opts = CompileOptions::new(S10_2800.clone(), dsp_target);
        let (stages, summaries) = plan_stages(&g, &opts).unwrap();
        (stages, summaries, opts)
    }

    #[test]
    fn balance_improves_imbalance() {
        let (mut stages, summaries, opts) = planned("resnet50", 1500, 0.85);
        let before = imbalance(&stages);
        let (_, incs) = balance(&mut stages, &summaries, &opts);
        let after = imbalance(&stages);
        assert!(incs > 0);
        assert!(
            after < before,
            "imbalance before={before:.1} after={after:.1}"
        );
    }

    #[test]
    fn dsp_budget_respected() {
        // The splits=1 baseline already costs some DSPs (one chain per
        // output column); the balancer must never *add* past the target.
        let (baseline_stages, _, _) = planned("resnet50", 0, 0.85);
        let baseline: usize = baseline_stages.iter().map(|s| s.resources.dsps).sum();
        for target in [50, 200, 1000] {
            let (mut stages, summaries, opts) = planned("resnet50", target, 0.85);
            balance(&mut stages, &summaries, &opts);
            let dsps: usize = stages.iter().map(|s| s.resources.dsps).sum();
            assert!(
                dsps <= target.max(baseline),
                "target {target}: used {dsps} (baseline {baseline})"
            );
        }
    }

    #[test]
    fn mobilenet_v2_hits_unroll_cap() {
        // With a huge budget, MobileNet-V2 must stop for lack of input
        // channels, not for lack of DSPs (the paper's 51% observation).
        let (mut stages, summaries, opts) = planned("mobilenet_v2", 1_000_000, 0.0);
        let (reason, _) = balance(&mut stages, &summaries, &opts);
        assert!(
            matches!(
                reason,
                StopReason::BottleneckAtUnrollCap | StopReason::BottleneckNotCompute
            ),
            "reason {reason:?}"
        );
    }

    #[test]
    fn splits_never_exceed_cap() {
        let (mut stages, summaries, opts) = planned("resnet50", 100_000, 0.85);
        balance(&mut stages, &summaries, &opts);
        for s in &stages {
            assert!(s.splits <= s.unroll_cap, "{}: {} > {}", s.name, s.splits, s.unroll_cap);
        }
    }

    #[test]
    fn zero_budget_makes_no_increments() {
        let (mut stages, summaries, opts) = planned("resnet50", 0, 0.85);
        let before: Vec<usize> = stages.iter().map(|s| s.splits).collect();
        let (reason, incs) = balance(&mut stages, &summaries, &opts);
        assert_eq!(incs, 0);
        assert_eq!(reason, StopReason::DspTargetReached);
        let after: Vec<usize> = stages.iter().map(|s| s.splits).collect();
        assert_eq!(before, after);
    }
}
