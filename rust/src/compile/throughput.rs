//! Per-stage cycle models (§IV).
//!
//! Two models, exactly as the paper describes:
//!
//! * the **naive linear model** — "Initially our model assumed a linear
//!   relationship between n_channel_splits and the throughput of a
//!   module" — cycles ∝ nonzeros / s;
//! * the **partition-aware model** — "we rectified this by computing the
//!   actual weight partitioning and padding that a later stage of the
//!   compiler performs, which improved our estimates to within 1%" —
//!   cycles from the real padded lock-step stream lengths.
//!
//! [`WeightSummary`] caches the per-output-channel row occupancy of a
//! pruned weight tensor so the balancer can re-evaluate a layer at a new
//! `s` in O(nonzero rows) without re-encoding values.

use crate::graph::{Op, Tensor};
use crate::sparsity::rle::RUNLENGTH_BITS;

/// Fixed per-output-line control overhead (address setup, new_oc
/// rotation, buffer handshake).
pub const LINE_OVERHEAD: u64 = 4;

/// Default PCIe feed rate for the Placeholder stage: bits accepted per
/// accelerator clock (PCIe gen3 x8 ≈ 50 Gb/s usable at ~500 MHz ≈ 100
/// bits/cycle; rounded to an activation-friendly 128).
pub const PCIE_BITS_PER_CYCLE: u64 = 128;

/// Row-occupancy summary of one pruned conv weight tensor.
///
/// A *row* is one (k_y, c_i) pair — the dimension the runlength walks and
/// the dimension `n_channel_splits` partitions (round-robin).
#[derive(Clone, Debug)]
pub struct WeightSummary {
    pub co: usize,
    pub rows: usize,
    /// per_oc[oc] = sorted (row index, nonzeros at that row across k_x).
    pub per_oc: Vec<Vec<(u32, u16)>>,
    pub total_nonzeros: usize,
}

impl WeightSummary {
    /// Build from HWIO conv weights.
    pub fn from_conv(w: &Tensor) -> WeightSummary {
        let (kh, kw, ci, co) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
        let rows = kh * ci;
        let mut per_oc: Vec<Vec<(u32, u16)>> = vec![Vec::new(); co];
        for ky in 0..kh {
            for c in 0..ci {
                let row = (ky * ci + c) as u32;
                for kx in 0..kw {
                    for oc in 0..co {
                        if w.data[((ky * kw + kx) * ci + c) * co + oc] != 0.0 {
                            match per_oc[oc].last_mut() {
                                Some((r, n)) if *r == row => *n += 1,
                                _ => per_oc[oc].push((row, 1)),
                            }
                        }
                    }
                }
            }
        }
        let total_nonzeros = per_oc
            .iter()
            .map(|v| v.iter().map(|&(_, n)| n as usize).sum::<usize>())
            .sum();
        WeightSummary {
            co,
            rows,
            per_oc,
            total_nonzeros,
        }
    }

    /// Build from MatMul weights (Ci, Co).
    pub fn from_matmul(w: &Tensor) -> WeightSummary {
        let as_conv = Tensor::from_vec(&[1, 1, w.shape[0], w.shape[1]], w.data.clone());
        WeightSummary::from_conv(&as_conv)
    }

    /// Lock-step padded stream length (cycles per line pass) for one
    /// output channel at `s` splits — matches `rle::encode_conv` exactly.
    pub fn oc_padded_len(&self, oc: usize, s: usize) -> u64 {
        let mut lens = vec![0u64; s];
        let mut last_local = vec![u64::MAX; s];
        self.accumulate_oc(oc, s, &mut lens, &mut last_local);
        lens.into_iter().max().unwrap_or(0)
    }

    /// Shared inner loop of the padded-length computations. `u64::MAX`
    /// in `last_local` marks "no entry yet". Scratch buffers are caller-
    /// provided so the balancer's hot loop does not allocate per output
    /// channel (perf-pass change; see EXPERIMENTS.md §Perf).
    #[inline]
    fn accumulate_oc(&self, oc: usize, s: usize, lens: &mut [u64], last_local: &mut [u64]) {
        let max_run = (1u64 << RUNLENGTH_BITS) - 1;
        for &(row, nnz) in &self.per_oc[oc] {
            let split = (row as usize) % s;
            let local = (row as usize / s) as u64;
            let gap = if last_local[split] == u64::MAX {
                local
            } else {
                local - last_local[split]
            };
            // pad entries for over-long runlengths + the real entries
            // (encoder inserts a pad only while gap > max_run)
            let pads = if gap == 0 { 0 } else { (gap - 1) / max_run };
            lens[split] += pads + nnz as u64;
            last_local[split] = local;
        }
    }

    /// Σ over output channels of the padded stream length — the cycles
    /// one full line pass takes (partition-aware). Also returns the total
    /// stored entries via `padded_both` for callers that need both.
    pub fn padded_cycles(&self, s: usize) -> u64 {
        self.padded_both(s).0
    }

    /// Weight-buffer entries including padding (memory footprint) at `s`.
    pub fn padded_entries(&self, s: usize) -> usize {
        self.padded_both(s).1
    }

    /// (lock-step cycles, stored entries) in one pass with reused scratch.
    pub fn padded_both(&self, s: usize) -> (u64, usize) {
        let mut lens = vec![0u64; s];
        let mut last_local = vec![u64::MAX; s];
        let mut cycles = 0u64;
        let mut entries = 0u64;
        for oc in 0..self.co {
            lens.fill(0);
            last_local.fill(u64::MAX);
            self.accumulate_oc(oc, s, &mut lens, &mut last_local);
            cycles += lens.iter().copied().max().unwrap_or(0);
            entries += lens.iter().sum::<u64>();
        }
        (cycles, entries as usize)
    }

    /// Naive linear estimate of the padded cycles.
    pub fn naive_cycles(&self, s: usize) -> u64 {
        (self.total_nonzeros as u64).div_ceil(s as u64)
    }
}

/// Cycle estimate for one stage at the given unroll. For compute stages
/// `summary` must be provided. `partition_aware` selects the model.
pub fn stage_cycles(
    op: &Op,
    geo: &crate::arch::StageGeometry,
    splits: usize,
    summary: Option<&WeightSummary>,
    partition_aware: bool,
) -> u64 {
    let out_h = geo.out_h as u64;
    let out_w = geo.out_w as u64;
    match op {
        Op::Conv2D { .. } => {
            let s = summary.expect("conv needs a weight summary");
            let per_line = if partition_aware {
                s.padded_cycles(splits)
            } else {
                s.naive_cycles(splits)
            };
            out_h * (per_line + LINE_OVERHEAD) + splits as u64 / 2
        }
        Op::DepthwiseConv2d { .. } => {
            // dense rows (k_y, c) split across s multipliers; each output
            // column is visited serially (no cross-channel DSP chain)
            let rows = (geo.kh * geo.in_c) as u64;
            let row_groups = rows.div_ceil(splits as u64);
            out_h * (out_w * row_groups * geo.kw as u64 + LINE_OVERHEAD)
        }
        Op::MatMul => {
            let s = summary.expect("matmul needs a weight summary");
            let per_pass = if partition_aware {
                s.padded_cycles(splits)
            } else {
                s.naive_cycles(splits)
            };
            per_pass + LINE_OVERHEAD + splits as u64 / 2
        }
        Op::MaxPool { ksize, .. } => {
            // channel-parallel comparator; k_w elements gathered per output
            out_h * (out_w * ksize.1 as u64 + LINE_OVERHEAD)
        }
        Op::Add | Op::BiasAdd | Op::Relu | Op::Relu6 | Op::Mul | Op::AddC => {
            // streaming: one line element-group per cycle
            out_h * (out_w + LINE_OVERHEAD)
        }
        Op::Mean => (geo.in_w as u64) * out_h.max(1) + LINE_OVERHEAD,
        Op::Softmax => geo.out_c as u64 + LINE_OVERHEAD,
        Op::Placeholder { .. } => {
            let bits = (geo.in_w * geo.in_c * 16) as u64 * out_h;
            bits.div_ceil(PCIE_BITS_PER_CYCLE)
        }
        Op::Pad { .. } => out_h * (out_w + LINE_OVERHEAD),
        Op::Const | Op::FusedBatchNorm { .. } => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::prune::prune_tensor;
    use crate::sparsity::rle::encode_conv;
    use crate::util::prop::Cases;
    use crate::util::Rng;

    /// The summary's fast path must agree exactly with the reference
    /// encoder's padded stream lengths.
    #[test]
    fn prop_summary_matches_encoder() {
        Cases::new(40).run(|rng, size| {
            let kh = 1 + size % 4;
            let kw = 1 + (size * 3) % 4;
            let ci = 1 + size % 10;
            let co = 1 + (size * 7) % 7;
            let mut w = Tensor::randn(&[kh, kw, ci, co], rng, 1.0);
            prune_tensor(&mut w, rng.f64() * 0.95);
            let s = 1 + rng.below(kh * ci);
            let rle = encode_conv(&w, s);
            let summary = WeightSummary::from_conv(&w);
            if summary.padded_cycles(s) != rle.total_cycles() as u64 {
                return Err(format!(
                    "padded_cycles {} != encoder {} (kh={kh} kw={kw} ci={ci} co={co} s={s})",
                    summary.padded_cycles(s),
                    rle.total_cycles()
                ));
            }
            if summary.padded_entries(s) != rle.total_entries() {
                return Err(format!(
                    "padded_entries {} != encoder {}",
                    summary.padded_entries(s),
                    rle.total_entries()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn naive_underestimates_at_high_splits() {
        let mut rng = Rng::new(8);
        let mut w = Tensor::randn(&[3, 3, 32, 16], &mut rng, 1.0);
        prune_tensor(&mut w, 0.85);
        let s = WeightSummary::from_conv(&w);
        // The naive model ignores lock-step padding, so it can only be
        // optimistic (the paper's motivation for the fix).
        for splits in [1, 2, 4, 8, 16, 32, 96] {
            assert!(
                s.naive_cycles(splits) <= s.padded_cycles(splits),
                "splits={splits}"
            );
        }
        let err1 = s.padded_cycles(1) as f64 / s.naive_cycles(1) as f64;
        let err32 = s.padded_cycles(32) as f64 / s.naive_cycles(32) as f64;
        assert!(err32 > err1, "padding penalty should grow with splits");
    }

    #[test]
    fn cycles_decrease_with_splits() {
        let mut rng = Rng::new(9);
        let mut w = Tensor::randn(&[3, 3, 64, 32], &mut rng, 1.0);
        prune_tensor(&mut w, 0.85);
        let summary = WeightSummary::from_conv(&w);
        let geo = crate::arch::StageGeometry {
            in_w: 14,
            in_c: 64,
            out_w: 14,
            out_h: 14,
            out_c: 32,
            kh: 3,
            kw: 3,
            stride: 1,
        };
        let op = Op::Conv2D {
            stride: (1, 1),
            padding: crate::graph::Padding::Same,
        };
        let c1 = stage_cycles(&op, &geo, 1, Some(&summary), true);
        let c8 = stage_cycles(&op, &geo, 8, Some(&summary), true);
        let c64 = stage_cycles(&op, &geo, 64, Some(&summary), true);
        assert!(c1 > c8 && c8 > c64, "{c1} {c8} {c64}");
    }

    #[test]
    fn placeholder_models_pcie() {
        let geo = crate::arch::StageGeometry {
            in_w: 224,
            in_c: 3,
            out_w: 224,
            out_h: 224,
            out_c: 3,
            kh: 1,
            kw: 1,
            stride: 1,
        };
        let c = stage_cycles(&Op::Placeholder { shape: vec![1, 224, 224, 3] }, &geo, 1, None, true);
        // 224*224*3*16 bits / 128 bits-per-cycle = 18,816 cycles
        assert_eq!(c, 224 * 224 * 3 * 16 / 128);
    }

    #[test]
    fn depthwise_cycles() {
        let geo = crate::arch::StageGeometry {
            in_w: 14,
            in_c: 512,
            out_w: 14,
            out_h: 14,
            out_c: 512,
            kh: 3,
            kw: 3,
            stride: 1,
        };
        let op = Op::DepthwiseConv2d {
            stride: (1, 1),
            padding: crate::graph::Padding::Same,
        };
        // rows = kh*C = 1536; serial over the 14 output columns, kw taps
        let c1 = stage_cycles(&op, &geo, 1, None, true);
        let c1536 = stage_cycles(&op, &geo, 1536, None, true);
        assert_eq!(c1, 14 * (14 * 1536 * 3 + LINE_OVERHEAD));
        assert_eq!(c1536, 14 * (14 * 3 + LINE_OVERHEAD));
        let c100 = stage_cycles(&op, &geo, 100, None, true);
        assert!(c100 > c1536 && c100 < c1);
    }
}
