//! The HPIPE network compiler (§IV, Fig 4).
//!
//! Input: an optimized graph (BNs folded, pads merged), a device + DSP
//! target, and optional precision annotations. Output: an
//! [`AcceleratorPlan`] — one parameterized hardware stage per graph node,
//! with `n_channel_splits` chosen by the balancer — which the generator
//! ([`codegen`]) turns into Verilog stubs + memory-initialization files,
//! and the simulator (`sim`) executes cycle-accurately.

pub mod balance;
pub mod codegen;
pub mod throughput;

use crate::arch::{
    conv_stage_cost, stage_cost_simple, CostModel, Device, FreqModel, Resources,
    StageGeometry,
};
use crate::graph::{Graph, GraphError, Op};
use crate::util::Json;
use std::collections::BTreeMap;
use throughput::{stage_cycles, WeightSummary};

/// One pipeline stage of the planned accelerator.
#[derive(Clone, Debug)]
pub struct StagePlan {
    pub name: String,
    pub op: Op,
    /// Producer stage names (activation inputs only, not weight consts).
    pub inputs: Vec<String>,
    pub geo: StageGeometry,
    /// n_channel_splits (1 for non-compute stages).
    pub splits: usize,
    /// Maximum useful splits (input-channel/row unroll cap).
    pub unroll_cap: usize,
    /// Multipliers instantiated (W·s for conv/dw, s for matmul).
    pub mults: usize,
    /// Estimated cycles per image (partition-aware model).
    pub cycles: u64,
    /// Weight buffer entries after padding (0 for non-compute).
    pub weight_entries: usize,
    pub resources: Resources,
    /// Input buffer capacity in lines (Add skip paths get deep buffers).
    pub buffer_lines: usize,
}

impl StagePlan {
    pub fn is_compute(&self) -> bool {
        self.op.is_compute()
    }
}

/// A fully planned accelerator.
#[derive(Clone, Debug)]
pub struct AcceleratorPlan {
    pub net_name: String,
    pub device: Device,
    pub stages: Vec<StagePlan>,
    pub totals: Resources,
    pub fmax_mhz: f64,
    /// Index of the stage with the highest cycles (the pipeline
    /// bottleneck that sets throughput).
    pub bottleneck: usize,
    pub dsp_target: usize,
}

impl AcceleratorPlan {
    /// Steady-state initiation interval in cycles (slowest stage).
    pub fn interval_cycles(&self) -> u64 {
        self.stages.iter().map(|s| s.cycles).max().unwrap_or(1)
    }

    /// Throughput at batch 1 in images/second.
    pub fn throughput_img_s(&self) -> f64 {
        self.fmax_mhz * 1e6 / self.interval_cycles() as f64
    }

    /// Rough latency estimate: pipeline fill (each stage must buffer k_h
    /// input lines before producing) plus one interval. The simulator
    /// refines this.
    pub fn latency_estimate_ms(&self) -> f64 {
        let fill: u64 = self
            .stages
            .iter()
            .map(|s| {
                // time for the producer to deliver kh lines ≈ kh *
                // (stage cycles / out_h)
                let per_line = s.cycles / (s.geo.out_h.max(1) as u64);
                per_line * s.geo.kh as u64
            })
            .sum();
        (fill + self.interval_cycles()) as f64 / (self.fmax_mhz * 1e6) * 1e3
    }

    pub fn stage(&self, name: &str) -> Option<&StagePlan> {
        self.stages.iter().find(|s| s.name == name)
    }

    /// Fraction of compute stages that are depthwise convolutions
    /// (frequency model input — the paper notes its pipelining heuristics
    /// were tuned on ResNet, leaving depthwise-heavy nets slower).
    pub fn depthwise_stage_frac(&self) -> f64 {
        let total = self.stages.iter().filter(|s| s.is_compute()).count();
        if total == 0 {
            return 0.0;
        }
        let dw = self
            .stages
            .iter()
            .filter(|s| matches!(s.op, Op::DepthwiseConv2d { .. }))
            .count();
        dw as f64 / total as f64
    }

    /// Serialize the plan (for reports and the codegen manifest).
    pub fn to_json(&self) -> Json {
        let mut stages = Json::Arr(vec![]);
        for s in &self.stages {
            let mut j = Json::obj();
            j.set("name", Json::from(s.name.as_str()))
                .set("op", Json::from(s.op.type_name()))
                .set("splits", Json::from(s.splits))
                .set("mults", Json::from(s.mults))
                .set("cycles", Json::from(s.cycles as f64))
                .set("weight_entries", Json::from(s.weight_entries))
                .set("dsps", Json::from(s.resources.dsps))
                .set("m20ks", Json::from(s.resources.m20ks))
                .set("alms", Json::from(s.resources.alms))
                .set("buffer_lines", Json::from(s.buffer_lines));
            stages.push(j);
        }
        let mut root = Json::obj();
        root.set("net", Json::from(self.net_name.as_str()))
            .set("device", Json::from(self.device.name))
            .set("fmax_mhz", Json::from(self.fmax_mhz))
            .set("dsp_target", Json::from(self.dsp_target))
            .set("interval_cycles", Json::from(self.interval_cycles() as f64))
            .set("throughput_img_s", Json::from(self.throughput_img_s()))
            .set("total_dsps", Json::from(self.totals.dsps))
            .set("total_m20ks", Json::from(self.totals.m20ks))
            .set("total_alms", Json::from(self.totals.alms))
            .set("stages", stages);
        root
    }
}

/// Compiler options.
#[derive(Clone, Debug)]
pub struct CompileOptions {
    pub device: Device,
    /// DSP budget the balancer fills toward (paper: 5000 on S10 2800).
    pub dsp_target: usize,
    pub cost_model: CostModel,
    pub freq_model: FreqModel,
    /// Use the partition-aware throughput model (§IV fix). The naive
    /// model is kept for the ablation bench.
    pub partition_aware: bool,
    /// Weight/activation precision in bits (Fig 4's precision
    /// annotations; §VI ran everything at 16). ≤9 bits enables the
    /// Agilex 2x dot-product packing of §VII.
    pub weight_bits: u32,
}

impl CompileOptions {
    pub fn new(device: Device, dsp_target: usize) -> CompileOptions {
        CompileOptions {
            device,
            dsp_target,
            cost_model: CostModel::default(),
            freq_model: FreqModel::default(),
            partition_aware: true,
            weight_bits: 16,
        }
    }

    /// Apply a precision annotation (Fig 4): adjusts weight-buffer entry
    /// width and activation width in the cost model.
    pub fn with_precision(mut self, bits: u32) -> CompileOptions {
        self.weight_bits = bits;
        self.cost_model.weight_entry_bits = bits as usize + 8; // + runlength/x fields
        self.cost_model.act_bits = bits as usize;
        self
    }
}

/// Build the initial (unbalanced, splits = 1) stage plans from a graph.
/// The graph must already be optimized (no BN/Mul/AddC/Pad left — those
/// have no hardware module).
pub fn plan_stages(
    graph: &Graph,
    opts: &CompileOptions,
) -> Result<(Vec<StagePlan>, Vec<Option<WeightSummary>>), GraphError> {
    let shapes = graph.infer_shapes()?;
    let order = graph.topo_order()?;
    let mut stages = Vec::new();
    let mut summaries = Vec::new();
    for idx in order {
        let n = &graph.nodes[idx];
        if matches!(n.op, Op::Const) {
            continue;
        }
        if matches!(n.op, Op::FusedBatchNorm { .. } | Op::Mul | Op::AddC | Op::Pad { .. }) {
            return Err(GraphError::Invalid(
                n.name.clone(),
                format!(
                    "op {} has no hardware module; run transform::optimize first",
                    n.op.type_name()
                ),
            ));
        }
        let out = &shapes[&n.name];
        // Activation input (first non-const input) drives the geometry.
        let act_inputs: Vec<String> = n
            .inputs
            .iter()
            .filter(|i| !matches!(graph.get(i).unwrap().op, Op::Const))
            .cloned()
            .collect();
        let in_shape = act_inputs
            .first()
            .map(|i| shapes[i].clone())
            .unwrap_or_else(|| out.clone());
        let (kh, kw, stride) = match &n.op {
            Op::Conv2D { stride, .. } | Op::DepthwiseConv2d { stride, .. } => {
                let w = &shapes[&n.inputs[1]];
                (w[0], w[1], stride.0)
            }
            Op::MaxPool { ksize, stride, .. } => (ksize.0, ksize.1, stride.0),
            _ => (1, 1, 1),
        };
        let geo = StageGeometry {
            in_w: if in_shape.len() == 4 { in_shape[2] } else { 1 },
            in_c: *in_shape.last().unwrap(),
            out_w: if out.len() == 4 { out[2] } else { 1 },
            out_h: if out.len() == 4 { out[1] } else { 1 },
            out_c: *out.last().unwrap(),
            kh,
            kw,
            stride,
        };
        // Weight summary + unroll cap for compute stages.
        let (summary, unroll_cap) = match &n.op {
            Op::Conv2D { .. } => {
                let w = graph.get(&n.inputs[1]).unwrap().value.as_ref().unwrap();
                (
                    Some(WeightSummary::from_conv(w)),
                    (w.shape[0] * w.shape[2]).max(1),
                )
            }
            Op::DepthwiseConv2d { .. } => (None, (geo.kh * geo.in_c).max(1)),
            Op::MatMul => {
                let w = graph.get(&n.inputs[1]).unwrap().value.as_ref().unwrap();
                (Some(WeightSummary::from_matmul(w)), w.shape[0].max(1))
            }
            _ => (None, 1),
        };
        let splits = 1usize;
        let mults = stage_mults(&n.op, &geo, splits);
        let cycles = stage_cycles(&n.op, &geo, splits, summary.as_ref(), opts.partition_aware);
        let weight_entries = summary
            .as_ref()
            .map(|s| s.padded_entries(splits))
            .unwrap_or(0);
        let buffer_lines = if n.op.buffers_input() {
            geo.kh + opts.cost_model.act_buffer_margin_lines
        } else {
            0 // streaming ops (BiasAdd/Relu/...) pass lines through
        };
        let resources = stage_resources(
            opts,
            &n.op,
            &geo,
            splits,
            mults,
            weight_entries,
            buffer_lines,
        );
        stages.push(StagePlan {
            name: n.name.clone(),
            op: n.op.clone(),
            inputs: act_inputs,
            geo,
            splits,
            unroll_cap,
            mults,
            cycles,
            weight_entries,
            resources,
            buffer_lines,
        });
        summaries.push(summary);
    }
    Ok((stages, summaries))
}

/// Multipliers instantiated for a stage at `s` splits: one DSP chain per
/// output column for convolutions (shared weight stream — Fig 6), a
/// single chain for MatMul.
pub fn stage_mults(op: &Op, geo: &StageGeometry, splits: usize) -> usize {
    match op {
        Op::Conv2D { .. } => geo.out_w * splits,
        // Depthwise units have no cross-channel reduction to chain, so
        // they unroll rows only (the paper's MobileNet-V2 bottleneck:
        // "the current version of HPIPE only unrolls the input channel
        // dimension").
        Op::DepthwiseConv2d { .. } => splits,
        Op::MatMul => splits,
        _ => 0,
    }
}

/// Resource cost dispatch.
pub fn stage_resources(
    opts: &CompileOptions,
    op: &Op,
    geo: &StageGeometry,
    splits: usize,
    mults: usize,
    weight_entries: usize,
    buffer_lines: usize,
) -> Resources {
    if op.is_compute() {
        conv_stage_cost(
            &opts.cost_model,
            geo,
            splits,
            mults,
            weight_entries,
            opts.device.mults_per_dsp_at(opts.weight_bits),
        )
    } else {
        stage_cost_simple(&opts.cost_model, op, geo, buffer_lines)
    }
}

/// Full compilation: plan, balance to the DSP target, size Add-path
/// buffers, estimate frequency.
pub fn compile(
    graph: &Graph,
    net_name: &str,
    opts: &CompileOptions,
) -> Result<AcceleratorPlan, GraphError> {
    let (mut stages, summaries) = plan_stages(graph, opts)?;
    balance::balance(&mut stages, &summaries, opts);
    size_add_buffers(&mut stages);

    // refresh costs after buffer sizing
    for st in stages.iter_mut() {
        st.resources = stage_resources(
            opts,
            &st.op,
            &st.geo,
            st.splits,
            st.mults,
            st.weight_entries,
            st.buffer_lines,
        );
    }

    let mut totals = Resources::default();
    for s in &stages {
        totals.add(&s.resources);
    }
    let alm_util = totals.alms as f64 / opts.device.alms as f64;
    let max_mults = stages.iter().map(|s| s.mults).max().unwrap_or(1);
    let bottleneck = stages
        .iter()
        .enumerate()
        .max_by_key(|(_, s)| s.cycles)
        .map(|(i, _)| i)
        .unwrap_or(0);
    let mut plan = AcceleratorPlan {
        net_name: net_name.to_string(),
        device: opts.device.clone(),
        stages,
        totals,
        fmax_mhz: 0.0,
        bottleneck,
        dsp_target: opts.dsp_target,
    };
    plan.fmax_mhz = opts.freq_model.fmax(
        &opts.device,
        max_mults,
        alm_util,
        plan.depthwise_stage_frac(),
    );
    Ok(plan)
}

/// §V-C: "The Add operation has one Input Activation Buffer for each
/// producer module, and the depth of each of these buffers is computed to
/// ensure the amount of buffering on skip paths matches the amount of
/// buffering on the non-skip path" — otherwise the pipeline deadlocks.
///
/// We compute, for each Add, the buffering depth (in lines) along each
/// input path back to the common ancestor, and give the Add's shallower
/// (skip) side the difference plus its own margin.
pub fn size_add_buffers(stages: &mut [StagePlan]) {
    let index: BTreeMap<String, usize> = stages
        .iter()
        .enumerate()
        .map(|(i, s)| (s.name.clone(), i))
        .collect();
    // path_depth[i] = max lines buffered from the input to stage i
    let mut depth: BTreeMap<String, usize> = BTreeMap::new();
    for i in 0..stages.len() {
        let s = &stages[i];
        let d = s
            .inputs
            .iter()
            .map(|p| depth.get(p).copied().unwrap_or(0))
            .max()
            .unwrap_or(0)
            + s.buffer_lines;
        depth.insert(s.name.clone(), d);
    }
    for i in 0..stages.len() {
        if !matches!(stages[i].op, Op::Add) || stages[i].inputs.len() != 2 {
            continue;
        }
        let d0 = depth.get(&stages[i].inputs[0]).copied().unwrap_or(0);
        let d1 = depth.get(&stages[i].inputs[1]).copied().unwrap_or(0);
        let diff = d0.abs_diff(d1);
        // The Add buffers both inputs; capacity must cover the imbalance.
        let need = diff + 2;
        if stages[i].buffer_lines < need {
            stages[i].buffer_lines = need;
        }
        let _ = index;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::S10_2800;
    use crate::nets::{resnet50, tiny_cnn, NetConfig};
    use crate::sparsity::prune_graph;
    use crate::transform::optimize;

    fn compiled_tiny() -> AcceleratorPlan {
        let g = tiny_cnn(NetConfig::test_scale());
        let (g, _) = optimize(&g);
        let opts = CompileOptions::new(S10_2800.clone(), 500);
        compile(&g, "tinycnn", &opts).unwrap()
    }

    #[test]
    fn tiny_plan_structure() {
        let plan = compiled_tiny();
        assert!(plan.stage("conv0").is_some());
        assert!(plan.stage("pool2").is_some());
        assert!(plan.stage("predictions").is_some());
        // every compute stage has multipliers and weight entries
        for s in plan.stages.iter().filter(|s| s.is_compute()) {
            assert!(s.mults > 0, "{}", s.name);
            assert!(s.weight_entries > 0, "{}", s.name);
            assert!(s.resources.dsps > 0, "{}", s.name);
        }
        assert!(plan.totals.dsps <= 500);
        assert!(plan.fmax_mhz > 100.0);
        assert!(plan.throughput_img_s() > 0.0);
    }

    #[test]
    fn unoptimized_graph_rejected() {
        let g = resnet50(NetConfig::test_scale()); // still has BN + Pad
        let opts = CompileOptions::new(S10_2800.clone(), 500);
        assert!(compile(&g, "resnet50", &opts).is_err());
    }

    #[test]
    fn balancing_raises_dsps_and_lowers_interval() {
        let g = tiny_cnn(NetConfig::test_scale());
        let (g, _) = optimize(&g);
        let lo = compile(&g, "t", &CompileOptions::new(S10_2800.clone(), 8)).unwrap();
        let hi = compile(&g, "t", &CompileOptions::new(S10_2800.clone(), 2000)).unwrap();
        assert!(hi.totals.dsps >= lo.totals.dsps);
        assert!(hi.interval_cycles() <= lo.interval_cycles());
    }

    #[test]
    fn add_buffers_sized_for_resnet_skip_paths() {
        let mut g = resnet50(NetConfig::test_scale());
        prune_graph(&mut g, 0.85);
        let (g, _) = optimize(&g);
        let opts = CompileOptions::new(S10_2800.clone(), 800);
        let plan = compile(&g, "resnet50", &opts).unwrap();
        // every residual Add must have a deeper buffer than the default
        let adds: Vec<&StagePlan> = plan
            .stages
            .iter()
            .filter(|s| matches!(s.op, Op::Add))
            .collect();
        assert_eq!(adds.len(), 16);
        assert!(
            adds.iter().all(|a| a.buffer_lines > 3),
            "Add buffers: {:?}",
            adds.iter().map(|a| a.buffer_lines).collect::<Vec<_>>()
        );
    }

    #[test]
    fn plan_json_roundtrips_through_parser() {
        let plan = compiled_tiny();
        let j = plan.to_json();
        let parsed = Json::parse(&j.pretty()).unwrap();
        assert_eq!(parsed.get("net").as_str(), Some("tinycnn"));
        assert!(parsed.get("stages").as_arr().unwrap().len() > 5);
    }

    #[test]
    fn bottleneck_is_max_cycles() {
        let plan = compiled_tiny();
        let max = plan.stages.iter().map(|s| s.cycles).max().unwrap();
        assert_eq!(plan.stages[plan.bottleneck].cycles, max);
        assert_eq!(plan.interval_cycles(), max);
    }
}
