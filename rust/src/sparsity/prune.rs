//! Magnitude pruning.
//!
//! The paper prunes 85% of weights from every convolution ("a different
//! pruning technique that does not restrict us to the same sparsity in
//! each layer" is left as future work — so we implement exactly the
//! uniform-per-layer scheme). Depthwise convolutions and biases are not
//! pruned (depthwise layers have too few weights per channel to survive
//! pruning; the paper's MobileNets run dense).

use crate::graph::{Graph, Op, Tensor};

/// Per-layer pruning outcome.
#[derive(Debug, Clone)]
pub struct PruneReport {
    /// (conv node name, weights pruned, weights total) per layer.
    pub layers: Vec<(String, usize, usize)>,
}

impl PruneReport {
    pub fn overall_sparsity(&self) -> f64 {
        let (z, t) = self
            .layers
            .iter()
            .fold((0usize, 0usize), |(z, t), (_, lz, lt)| (z + lz, t + lt));
        if t == 0 {
            0.0
        } else {
            z as f64 / t as f64
        }
    }
}

/// Zero out the smallest-magnitude `fraction` of a tensor's elements.
/// Exact: prunes floor(fraction * len) elements, ties broken by index.
pub fn prune_tensor(t: &mut Tensor, fraction: f64) -> usize {
    assert!((0.0..=1.0).contains(&fraction));
    let k = (t.data.len() as f64 * fraction).floor() as usize;
    if k == 0 {
        return 0;
    }
    let mut idx: Vec<usize> = (0..t.data.len()).collect();
    idx.sort_by(|&a, &b| {
        t.data[a]
            .abs()
            .partial_cmp(&t.data[b].abs())
            .unwrap()
            .then(a.cmp(&b))
    });
    for &i in &idx[..k] {
        t.data[i] = 0.0;
    }
    k
}

/// Prune every Conv2D / MatMul weight tensor in the graph to the target
/// per-layer sparsity. Depthwise weights and non-weight constants are
/// untouched.
pub fn prune_graph(g: &mut Graph, fraction: f64) -> PruneReport {
    // Identify weight const inputs of prunable compute nodes.
    let targets: Vec<(String, String)> = g
        .nodes
        .iter()
        .filter_map(|n| match n.op {
            Op::Conv2D { .. } | Op::MatMul => {
                Some((n.name.clone(), n.inputs[1].clone()))
            }
            _ => None,
        })
        .collect();
    let mut layers = Vec::new();
    for (layer, wname) in targets {
        let t = g
            .get_mut(&wname)
            .and_then(|n| n.value.as_mut())
            .expect("weight const");
        let total = t.data.len();
        let pruned = prune_tensor(t, fraction);
        layers.push((layer, pruned, total));
    }
    PruneReport { layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{resnet50, NetConfig};
    use crate::util::prop::Cases;
    use crate::util::Rng;

    #[test]
    fn prune_tensor_exact_count() {
        let mut rng = Rng::new(1);
        let mut t = Tensor::randn(&[1000], &mut rng, 1.0);
        let k = prune_tensor(&mut t, 0.85);
        assert_eq!(k, 850);
        assert_eq!(t.data.iter().filter(|&&x| x == 0.0).count(), 850);
    }

    #[test]
    fn prune_keeps_largest() {
        let mut t = Tensor::from_vec(&[5], vec![0.1, -5.0, 0.2, 3.0, -0.05]);
        prune_tensor(&mut t, 0.6);
        assert_eq!(t.data, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn prune_zero_fraction_is_noop() {
        let mut t = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        assert_eq!(prune_tensor(&mut t, 0.0), 0);
        assert_eq!(t.data, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn graph_prune_hits_target() {
        let mut g = resnet50(NetConfig::test_scale());
        let report = prune_graph(&mut g, 0.85);
        assert_eq!(report.layers.len(), 53 + 1); // 53 convs + FC
        let s = report.overall_sparsity();
        assert!((s - 0.85).abs() < 0.01, "sparsity={s}");
        // every pruned layer individually near target
        for (name, z, t) in &report.layers {
            let ls = *z as f64 / *t as f64;
            assert!((ls - 0.85).abs() < 0.02, "{name}: {ls}");
        }
    }

    #[test]
    fn depthwise_not_pruned() {
        let mut g = crate::nets::mobilenet_v1(NetConfig::test_scale());
        prune_graph(&mut g, 0.85);
        let w = g
            .get("Conv2d_1_depthwise/depthwise_weights")
            .unwrap()
            .value
            .as_ref()
            .unwrap();
        assert_eq!(w.sparsity(), 0.0);
    }

    #[test]
    fn prop_prune_preserves_surviving_values() {
        Cases::new(32).run(|rng, size| {
            let n = size * 20 + 5;
            let orig = Tensor::randn(&[n], rng, 1.0);
            let mut t = orig.clone();
            let frac = rng.f64() * 0.9;
            prune_tensor(&mut t, frac);
            for (a, b) in t.data.iter().zip(&orig.data) {
                if *a != 0.0 && a != b {
                    return Err(format!("survivor changed: {a} vs {b}"));
                }
            }
            let zeros = t.data.iter().filter(|&&x| x == 0.0).count();
            let expect = (n as f64 * frac).floor() as usize;
            if zeros < expect {
                return Err(format!("zeros {zeros} < expected {expect}"));
            }
            Ok(())
        });
    }
}
