//! Weight pruning and HPIPE's compressed weight representation.
//!
//! §II-B / §V-B of the paper: weights are magnitude-pruned (85% for the
//! ResNet-50 experiments, "the same sparsity in each layer"), then stored
//! as a compressed stream per output channel: *runlengths* that encode
//! the (y, z) position of each nonzero as an offset from the previous
//! nonzero, plus an *x-index* that drives the k_w-to-1 X-mux in front of
//! each multiplier. The `n_channel_splits` parameter partitions the
//! stream rows across parallel weight buffers; because splits process in
//! lock-step, every split's stream is padded to the longest one — the
//! nonlinearity that made the paper's naive throughput model wrong by
//! enough to matter (§IV: fixing it brought estimates within 1% and
//! bought 23% throughput).

pub mod prune;
pub mod rle;

pub use prune::{prune_graph, prune_tensor, PruneReport};
pub use rle::{encode_conv, encode_matmul, ConvRle, SplitStream, WeightEntry, RUNLENGTH_BITS};
