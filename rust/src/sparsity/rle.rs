//! HPIPE's runlength-encoded weight streams (§V-B) and the
//! `n_channel_splits` partitioner.
//!
//! For each output channel, the nonzero weights are ordered by *row* —
//! a row is one (k_y, c_i) pair, the dimension the Input Buffer
//! Controller walks — and each nonzero is stored as:
//!
//! * `runlength`: how many rows to advance from the previous entry
//!   (0 = same row, another nonzero at a different x);
//! * `x`: the k_w-to-1 X-mux selector (the weight's kernel-x position);
//! * `value`: the weight itself (quantized at codegen time).
//!
//! The runlength field is [`RUNLENGTH_BITS`] wide; a gap longer than the
//! field can express requires inserting *pad entries* (zero weights that
//! only advance the row counter). With `n_channel_splits = s`, rows are
//! dealt round-robin across `s` streams that execute in lock-step, so
//! every stream is padded to the longest stream's length. Both padding
//! effects are why layer throughput is not linear in `s` — the
//! partition-aware throughput model (compile::throughput) calls
//! [`encode_conv`] to get the *real* padded lengths, which is the §IV fix
//! that brought the cycle estimates within 1%.

use crate::graph::Tensor;

/// Width of the runlength field in the weight buffer word. 4 bits is the
/// paper-plausible choice (runlength + x-index + 16-bit weight pack into
/// one M20K word); the ablation bench sweeps this.
pub const RUNLENGTH_BITS: u32 = 4;

/// One weight-buffer word.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WeightEntry {
    /// Rows advanced since the previous entry (within this split).
    pub runlength: u32,
    /// Kernel-x position (X-mux select).
    pub x: u8,
    /// Weight value; 0.0 for pad entries.
    pub value: f32,
}

/// The entries of one (output channel, split) stream.
#[derive(Clone, Debug, Default)]
pub struct SplitStream {
    pub entries: Vec<WeightEntry>,
    /// Entries that are real nonzeros (not runlength/lockstep padding).
    pub nonzeros: usize,
}

/// A fully encoded convolution weight tensor.
#[derive(Clone, Debug)]
pub struct ConvRle {
    pub kh: usize,
    pub kw: usize,
    pub ci: usize,
    pub co: usize,
    pub splits: usize,
    /// streams[oc][split]
    pub streams: Vec<Vec<SplitStream>>,
}

impl ConvRle {
    /// Lock-step stream length for an output channel: the max split
    /// stream length (shorter splits idle — "padding" in the paper).
    pub fn oc_cycles(&self, oc: usize) -> usize {
        self.streams[oc]
            .iter()
            .map(|s| s.entries.len())
            .max()
            .unwrap_or(0)
    }

    /// Total lock-step cycles to stream every output channel once.
    pub fn total_cycles(&self) -> usize {
        (0..self.co).map(|oc| self.oc_cycles(oc)).sum()
    }

    /// Total real nonzeros across all streams.
    pub fn total_nonzeros(&self) -> usize {
        self.streams
            .iter()
            .flat_map(|per_oc| per_oc.iter())
            .map(|s| s.nonzeros)
            .sum()
    }

    /// Total entries including padding (weight-buffer M20K footprint).
    pub fn total_entries(&self) -> usize {
        self.streams
            .iter()
            .flat_map(|per_oc| per_oc.iter())
            .map(|s| s.entries.len())
            .sum()
    }

    /// Padding overhead ratio: entries / nonzeros (1.0 = no padding).
    pub fn padding_overhead(&self) -> f64 {
        let nz = self.total_nonzeros();
        if nz == 0 {
            1.0
        } else {
            self.total_entries() as f64 / nz as f64
        }
    }
}

/// One decoded nonzero from an RLE stream walk: the absolute (k_y, c_i)
/// row index, the kernel-x position, and the weight value. Pad entries
/// (zero weights that only advance the row counter) are consumed by the
/// decoder and never yielded.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Nonzero {
    /// Absolute row = k_y * c_i_total + c_i (split-interleaving undone).
    pub row: usize,
    /// Kernel-x position (the X-mux select).
    pub x: usize,
    pub value: f32,
}

impl ConvRle {
    /// Walk every real nonzero of output channel `oc`, runlength-decoding
    /// split by split (split 0's entries first, then split 1's, ...).
    ///
    /// This is the **one** runlength decoder: [`decode_conv`], the
    /// executor's plan-time pre-decode (`exec::sparse::pack_rle`) and the
    /// PR 3 baseline kernels all walk streams through it. The production
    /// execution hot path decodes at *plan build only* — see
    /// `exec::sparse` — so this iterator never runs per-inference there.
    pub fn nonzeros(&self, oc: usize) -> impl Iterator<Item = Nonzero> + '_ {
        let splits = self.splits;
        self.streams[oc].iter().enumerate().flat_map(move |(split, stream)| {
            // The first entry's runlength is its absolute split-local
            // row; each later entry advances from the previous one.
            let mut local_row = 0usize;
            let mut first = true;
            stream.entries.iter().filter_map(move |e| {
                if first {
                    local_row = e.runlength as usize;
                    first = false;
                } else {
                    local_row += e.runlength as usize;
                }
                if e.value == 0.0 {
                    None // pad entry: only advances the counter
                } else {
                    Some(Nonzero {
                        row: local_row * splits + split,
                        x: e.x as usize,
                        value: e.value,
                    })
                }
            })
        })
    }
}

/// Encode a conv weight tensor (HWIO) into per-(oc, split) streams.
/// Rows (k_y, c_i) are dealt round-robin across `splits` streams.
pub fn encode_conv(w: &Tensor, splits: usize) -> ConvRle {
    assert_eq!(w.shape.len(), 4, "expected HWIO conv weights");
    let (kh, kw, ci, co) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    assert!(splits >= 1);
    let max_run = (1u32 << RUNLENGTH_BITS) - 1;
    let rows = kh * ci;

    let mut streams: Vec<Vec<SplitStream>> = Vec::with_capacity(co);
    for oc in 0..co {
        let mut per_split: Vec<SplitStream> = vec![SplitStream::default(); splits];
        // split-local row counters: position of the previous entry
        let mut last_row: Vec<Option<usize>> = vec![None; splits];
        for row in 0..rows {
            let (ky, c) = (row / ci, row % ci);
            let split = row % splits;
            let local_row = row / splits; // row index within this split
            for kx in 0..kw {
                let v = w.data[((ky * kw + kx) * ci + c) * co + oc];
                if v == 0.0 {
                    continue;
                }
                let stream = &mut per_split[split];
                let mut gap = match last_row[split] {
                    None => local_row as u32,
                    Some(prev) => (local_row - prev) as u32,
                };
                // insert pad entries for gaps beyond the field width
                while gap > max_run {
                    stream.entries.push(WeightEntry {
                        runlength: max_run,
                        x: 0,
                        value: 0.0,
                    });
                    gap -= max_run;
                }
                stream.entries.push(WeightEntry {
                    runlength: gap,
                    x: kx as u8,
                    value: v,
                });
                stream.nonzeros += 1;
                last_row[split] = Some(local_row);
            }
        }
        streams.push(per_split);
    }
    ConvRle {
        kh,
        kw,
        ci,
        co,
        splits,
        streams,
    }
}

/// Encode MatMul weights (Ci, Co) — a 1×1 "conv" over a 1×1 image.
pub fn encode_matmul(w: &Tensor, splits: usize) -> ConvRle {
    assert_eq!(w.shape.len(), 2);
    let (ci, co) = (w.shape[0], w.shape[1]);
    let as_conv = Tensor::from_vec(&[1, 1, ci, co], w.data.clone());
    encode_conv(&as_conv, splits)
}

/// Decode back to a dense tensor — used by tests to prove the encoding
/// is lossless, and by codegen's memory-init verifier.
pub fn decode_conv(rle: &ConvRle) -> Tensor {
    let (kh, kw, ci, co) = (rle.kh, rle.kw, rle.ci, rle.co);
    let mut out = Tensor::zeros(&[kh, kw, ci, co]);
    for oc in 0..co {
        for nz in rle.nonzeros(oc) {
            let (ky, c) = (nz.row / ci, nz.row % ci);
            out.data[((ky * kw + nz.x) * ci + c) * co + oc] = nz.value;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::prune::prune_tensor;
    use crate::util::prop::Cases;
    use crate::util::Rng;

    fn random_sparse(rng: &mut Rng, shape: &[usize], sparsity: f64) -> Tensor {
        let mut t = Tensor::randn(shape, rng, 1.0);
        prune_tensor(&mut t, sparsity);
        t
    }

    #[test]
    fn roundtrip_dense() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[3, 3, 4, 5], &mut rng, 1.0);
        for splits in [1, 2, 3, 4, 12] {
            let rle = encode_conv(&w, splits);
            let back = decode_conv(&rle);
            assert_eq!(back.data, w.data, "splits={splits}");
        }
    }

    #[test]
    fn roundtrip_sparse_all_split_counts() {
        Cases::new(40).run(|rng, size| {
            let kh = 1 + size % 5;
            let kw = 1 + (size * 7) % 5;
            let ci = 1 + size % 9;
            let co = 1 + (size * 3) % 6;
            let sp = rng.f64() * 0.95;
            let w = random_sparse(rng, &[kh, kw, ci, co], sp);
            let splits = 1 + rng.below(kh * ci);
            let rle = encode_conv(&w, splits);
            let back = decode_conv(&rle);
            if back.data == w.data {
                Ok(())
            } else {
                Err(format!(
                    "mismatch kh={kh} kw={kw} ci={ci} co={co} splits={splits} sp={sp:.2}"
                ))
            }
        });
    }

    #[test]
    fn nonzeros_iterator_yields_every_weight_once() {
        Cases::new(24).seed(0xDEC0).run(|rng, size| {
            let kh = 1 + size % 4;
            let kw = 1 + (size * 3) % 4;
            let ci = 1 + size % 7;
            let co = 1 + (size * 5) % 5;
            let w = random_sparse(rng, &[kh, kw, ci, co], rng.f64() * 0.95);
            let splits = 1 + rng.below(kh * ci);
            let rle = encode_conv(&w, splits);
            for oc in 0..co {
                let mut seen = 0usize;
                for nz in rle.nonzeros(oc) {
                    let (ky, c) = (nz.row / ci, nz.row % ci);
                    let want = w.data[((ky * kw + nz.x) * ci + c) * co + oc];
                    if nz.value != want {
                        return Err(format!(
                            "oc={oc} row={} x={} decoded {} != stored {want}",
                            nz.row, nz.x, nz.value
                        ));
                    }
                    seen += 1;
                }
                let expect = (0..kh * kw * ci)
                    .filter(|i| {
                        let (k, c) = (i / ci, i % ci);
                        w.data[(k * ci + c) * co + oc] != 0.0
                    })
                    .count();
                if seen != expect {
                    return Err(format!("oc={oc}: {seen} nonzeros != {expect}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn nonzero_counting() {
        let mut rng = Rng::new(3);
        let w = random_sparse(&mut rng, &[3, 3, 8, 16], 0.85);
        let expected_nz = w.data.iter().filter(|&&v| v != 0.0).count();
        let rle = encode_conv(&w, 4);
        assert_eq!(rle.total_nonzeros(), expected_nz);
        assert!(rle.total_entries() >= expected_nz);
    }

    #[test]
    fn lockstep_padding_grows_with_splits() {
        // With extreme splits, imbalance padding must push the padded
        // cycle count above nnz/splits.
        let mut rng = Rng::new(4);
        let w = random_sparse(&mut rng, &[3, 3, 16, 8], 0.9);
        let rle1 = encode_conv(&w, 1);
        let rle8 = encode_conv(&w, 8);
        let ideal8 = (rle1.total_cycles() as f64 / 8.0).ceil() as usize;
        assert!(
            rle8.total_cycles() >= ideal8,
            "padded {} < ideal {}",
            rle8.total_cycles(),
            ideal8
        );
        // and the speedup is sublinear (the paper's nonlinearity)
        let speedup = rle1.total_cycles() as f64 / rle8.total_cycles() as f64;
        assert!(speedup < 8.0, "speedup={speedup}");
        assert!(speedup > 1.5, "speedup={speedup}");
    }

    #[test]
    fn long_gap_inserts_pad_entries() {
        // single nonzero at the last row, runlength 4 bits => row index
        // beyond 15 needs pads
        let mut w = Tensor::zeros(&[1, 1, 40, 1]);
        w.data[39] = 2.5;
        let rle = encode_conv(&w, 1);
        let s = &rle.streams[0][0];
        assert!(s.entries.len() > 1, "need pad entries, got {:?}", s.entries);
        assert_eq!(s.nonzeros, 1);
        assert_eq!(decode_conv(&rle).data, w.data);
    }

    #[test]
    fn matmul_encoding() {
        let mut rng = Rng::new(5);
        let w = random_sparse(&mut rng, &[64, 10], 0.85);
        let rle = encode_matmul(&w, 8);
        assert_eq!(rle.co, 10);
        let back = decode_conv(&rle);
        assert_eq!(back.data, w.data);
    }

    #[test]
    fn empty_output_channel_zero_cycles() {
        let w = Tensor::zeros(&[3, 3, 4, 2]);
        let rle = encode_conv(&w, 2);
        assert_eq!(rle.total_cycles(), 0);
        assert_eq!(rle.padding_overhead(), 1.0);
    }

    #[test]
    fn dense_padding_overhead_is_one_when_splits_divide() {
        let mut rng = Rng::new(6);
        // fully dense, rows divisible by splits -> perfectly balanced
        let w = Tensor::randn(&[2, 3, 8, 4], &mut rng, 1.0);
        let rle = encode_conv(&w, 4); // 16 rows / 4 splits = 4 each
        assert!((rle.padding_overhead() - 1.0).abs() < 1e-9);
        let ideal = rle.total_nonzeros() / 4 / rle.co;
        assert_eq!(rle.oc_cycles(0), ideal);
    }
}
