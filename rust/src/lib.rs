//! HPIPE: Heterogeneous Layer-Pipelined and Sparse-Aware CNN Inference.
//!
//! A software reproduction of Hall & Betz (FCCM 2020): the HPIPE network
//! compiler, the layer-pipelined sparse-aware accelerator architecture
//! (as a cycle-level simulator standing in for the Stratix 10 device),
//! all the baselines the paper compares against, and a serving runtime
//! that executes graphs through the compiled sparse-aware execution
//! engine ([`exec`]) — planned once per graph, zero-skipping over RLE
//! weight streams, checked against the reference interpreter oracle.
//!
//! See DESIGN.md for the module map and EXPERIMENTS.md for measured
//! reproductions of every table and figure.

pub mod arch;
pub mod artifact;
pub mod baselines;
pub mod compile;
pub mod coordinator;
pub mod exec;
pub mod graph;
pub mod interp;
pub mod nets;
pub mod runtime;
pub mod sim;
pub mod sparsity;
pub mod transform;
pub mod util;
